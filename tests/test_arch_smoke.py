"""Per-architecture smoke tests (assignment requirement).

Every assigned arch instantiates a REDUCED same-family config and runs one
forward/train step on CPU, asserting output shapes + no NaNs; plus the
prefill->decode cache-consistency check (decode logits == full-forward
logits at the same position) which exercises every cache type: GQA KV, MLA
latent (absorbed decode), Mamba conv+SSD state, hybrid, and whisper
self+cross.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get, tiny_variant
from repro.data import TokenPipeline
from repro.launch import steps
from repro.models import encdec, lm


def _batch(cfg, B=2, S=32):
    pipe = TokenPipeline(cfg.vocab_size, S, B)
    b = pipe.batch(0)
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vit_stub":
        ft = cfg.frontend_tokens
        b = {"tokens": b["tokens"][:, : S - ft], "labels": b["labels"],
             "patch_embeds": jnp.zeros((B, ft, cfg.d_model), cfg.dtype)}
    return b


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step(name):
    cfg = tiny_variant(get(name))
    state = steps.init_state(cfg, 0)
    ts = jax.jit(steps.make_train_step(cfg))
    b = _batch(cfg)
    state2, m = ts(state, b)
    assert np.isfinite(float(m["loss"])), m
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    def diff(a, c):
        return float(jnp.abs(a - c).max())
    deltas = jax.tree.map(diff, state["params"], state2["params"])
    assert max(jax.tree.leaves(deltas)) > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes(name):
    cfg = tiny_variant(get(name))
    params = steps.init_state(cfg, 0)["params"]
    b = _batch(cfg)
    fwd = steps._forward_for(cfg)
    logits, _, aux = fwd(params, b, "train", None, None)
    B, S = b["labels"].shape
    from repro.models.layers import padded_vocab

    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits[..., : cfg.vocab_size]).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_consistency(name):
    cfg = tiny_variant(get(name)).replace(capacity_factor=8.0)
    params = steps.init_state(cfg, 0)["params"]
    B, S, CACHE = 2, 16, 40
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                cfg.vocab_size)
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(jax.random.key(2),
                                   (B, cfg.encoder_seq, cfg.d_model)) * 0.02
        full, _, _ = encdec.forward(params, cfg, tokens, frames, mode="train")
        _, caches, _ = encdec.forward(params, cfg, tokens[:, :S], frames,
                                      mode="prefill", cache_len=CACHE)
        dlogits, _, _ = encdec.forward(params, cfg, tokens[:, S:S + 1], None,
                                       mode="decode", caches=caches, pos=S)
        off = 0
    else:
        pe = None
        if cfg.frontend == "vit_stub":
            pe = jax.random.normal(jax.random.key(3),
                                   (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
        full, _, _ = lm.forward(params, cfg, tokens, mode="train",
                                prefix_embeds=pe)
        _, caches, _ = lm.forward(params, cfg, tokens[:, :S], mode="prefill",
                                  prefix_embeds=pe, cache_len=CACHE)
        off = cfg.frontend_tokens if pe is not None else 0
        dlogits, _, _ = lm.forward(params, cfg, tokens[:, S:S + 1],
                                   mode="decode", caches=caches, pos=S + off)
    want = full[:, S + off, : cfg.vocab_size]
    got = dlogits[:, 0, : cfg.vocab_size]
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4 * scale, rtol=1e-3)


def test_layer_plans():
    """Hybrid/MoE layer planning matches the published interleaves."""
    from repro.models.lm import layer_plan, segments

    jamba = get("jamba-1.5-large-398b")
    plan = layer_plan(jamba)
    assert len(plan) == 72
    assert sum(1 for m, _ in plan if m == "gqa") == 9       # 1:7 attention
    assert sum(1 for _, f in plan if f == "moe") == 36      # MoE every 2nd
    assert plan[4][0] == "gqa" and plan[3][0] == "mamba"
    segs = segments(jamba)
    assert segs[-1][1] == 9 and len(segs[-1][0]) == 8       # 9 periods of 8

    ds = get("deepseek-v2-236b")
    plan = layer_plan(ds)
    assert plan[0] == ("mla", "dense") and plan[1] == ("mla", "moe")
    assert sum(1 for _, f in plan if f == "moe") == 59

    m2 = get("mamba2-370m")
    assert all(p == ("mamba", "none") for p in layer_plan(m2))


def test_param_counts_in_published_range():
    """Analytic param counts should land near the published sizes."""
    expect = {
        "granite-8b": (7.0e9, 9.5e9),
        "granite-3-2b": (2.0e9, 3.3e9),
        "qwen2-0.5b": (0.4e9, 0.7e9),
        "minitron-8b": (7.5e9, 10.0e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "granite-moe-3b-a800m": (2.2e9, 4.2e9),
        "internvl2-26b": (1.7e10, 2.4e10),   # LM backbone (ViT is the stub)
        "jamba-1.5-large-398b": (3.4e11, 4.4e11),
        "whisper-base": (0.5e8, 1.2e8),
    }
    for name, (lo, hi) in expect.items():
        n = get(name).num_params()
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_active_params_moe():
    ds = get("deepseek-v2-236b")
    total, active = ds.num_params(), ds.active_params()
    assert active < 0.2 * total  # ~21B active of 236B
