"""Grouped-conv family tests: depthwise/pointwise Pallas kernels (interpret
mode) against the lax.conv_general_dilated ground truth across strides and
channel counts, group-aware ConvSpec accounting, tuner coverage, and the
MobileNet-style forward under a tuned per-layer plan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import spy_algorithms as _spy_algorithms
from repro.configs import get, tiny_variant
from repro.core import ConvSpec, InferenceEngine, conv2d
from repro.core.autotune import cost_model_select, measured_select
from repro.kernels import ops, ref

KEY = jax.random.key(0)

# (H, W, C) x stride — odd sizes and ragged channel counts included
DW_CASES = [
    (16, 16, 8, 1),
    (16, 16, 8, 2),
    (14, 14, 96, 1),    # MobileNetV2 s4 shape
    (14, 14, 144, 2),   # strided downsample, C > one lane block
    (13, 11, 40, 2),    # odd dims: SAME padding asymmetry under stride
    (7, 7, 160, 1),
]


def _dw_inputs(h, w, c, dtype=jnp.float32):
    x = jax.random.normal(KEY, (1, h, w, c), dtype)
    wgt = jax.random.normal(jax.random.fold_in(KEY, 7), (3, 3, 1, c), dtype)
    return x, wgt


@pytest.mark.parametrize("case", DW_CASES, ids=str)
def test_depthwise_kernel_vs_ground_truth(case):
    h, w, c, stride = case
    x, wgt = _dw_inputs(h, w, c)
    gt = ref.conv2d_reference(x, wgt, stride=stride, groups=c)
    xp = ref.pad_same(x, 3, 3, stride=stride)
    y = ops.depthwise(xp, wgt, impl="pallas", stride=stride)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(gt).max()))


@pytest.mark.parametrize("block_c", [8, 32, 128, 512])
def test_depthwise_block_sweep(block_c):
    x, wgt = _dw_inputs(10, 12, 48)  # 48 % 32 != 0: ragged last block
    gt = ref.conv2d_reference(x, wgt, groups=48)
    xp = ref.pad_same(x, 3, 3)
    y = ops.depthwise(xp, wgt, impl="pallas", block_c=block_c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-4,
                               atol=1e-4)


def test_depthwise_pallas_vs_structural_ref():
    x, wgt = _dw_inputs(12, 12, 32)
    xp = ref.pad_same(x, 3, 3, stride=2)
    y_pl = ops.depthwise(xp, wgt, impl="pallas", stride=2)
    y_ref = ops.depthwise(xp, wgt, impl="jnp", stride=2)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ck", [(8, 16), (48, 24), (96, 576), (130, 40)])
def test_pointwise_kernel_vs_ground_truth(ck):
    c, k = ck
    x = jax.random.normal(KEY, (1, 9, 11, c))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 1, c, k))
    gt = ref.conv2d_reference(x, wgt)
    for block_k in (16, 128, 512):
        y = ops.pointwise(x, wgt, impl="pallas", block_k=block_k)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(gt), rtol=1e-4,
            atol=1e-4 * float(jnp.abs(gt).max()), err_msg=str(block_k))


@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_grouped_routing(stride):
    """conv2d detects groups from the filter shape and matches lax for
    both the auto (tuned) path and the xla escape hatch."""
    x = jax.random.normal(KEY, (1, 16, 16, 24))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 5), (3, 3, 1, 24))
    gt = ref.conv2d_reference(x, wgt, stride=stride, groups=24)
    for algorithm in ("auto", "xla"):
        y = conv2d(x, wgt, stride=stride, algorithm=algorithm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-4,
                                   atol=1e-4, err_msg=algorithm)


def test_conv2d_grouped_non_depthwise_falls_back():
    """groups > 1 but != C (grouped, not depthwise): XLA reference path."""
    x = jax.random.normal(KEY, (1, 8, 8, 16))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 6), (3, 3, 4, 32))
    gt = ref.conv2d_reference(x, wgt, groups=4)
    y = conv2d(x, wgt, algorithm="auto")
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-5,
                               atol=1e-5)


def test_convspec_group_accounting():
    """Depthwise flops/bytes divide the dense C*K product by groups."""
    dense = ConvSpec(h=14, w=14, c=96, k=96)
    dw = ConvSpec(h=14, w=14, c=96, k=96, groups=96)
    assert dw.flops == dense.flops // 96
    el = 4
    assert dw.bytes_min == dense.bytes_min - el * 3 * 3 * 96 * 95  # filters


def test_convspec_from_tensors_group_aware():
    """Depthwise weights (r,s,1,c) must produce groups=c, not a wrong c."""
    x = jax.random.normal(KEY, (1, 8, 8, 24))
    wgt = jax.random.normal(KEY, (3, 3, 1, 24))
    spec = ConvSpec.from_tensors(x, wgt, 2)
    assert (spec.c, spec.k, spec.groups, spec.stride) == (24, 24, 24, 2)
    assert spec.depthwise
    # dense filters unchanged
    wd = jax.random.normal(KEY, (3, 3, 24, 32))
    spec = ConvSpec.from_tensors(x, wd, 1)
    assert (spec.c, spec.k, spec.groups) == (24, 32, 1)


def test_tuner_on_grouped_specs():
    """Cost model and measured mode both pick the grouped kernels for
    grouped specs, including strided depthwise (in-kernel downsample)."""
    for stride in (1, 2):
        spec = ConvSpec(h=16, w=16, c=96, k=96, groups=96, stride=stride)
        assert cost_model_select(spec).algorithm == "depthwise"
        assert measured_select(spec, repeats=1).algorithm == "depthwise"
    pw = ConvSpec(h=16, w=16, c=96, k=192, r=1, s=1)
    assert cost_model_select(pw).algorithm == "pointwise"
    assert measured_select(pw, repeats=1).algorithm == "pointwise"
    # strided pointwise subsamples in-kernel (ResNet projection shortcuts)
    assert cost_model_select(
        ConvSpec(h=16, w=16, c=96, k=192, r=1, s=1, stride=2)
    ).algorithm == "pointwise"
    # grouped-non-depthwise: no kernel family -> xla
    assert cost_model_select(
        ConvSpec(h=16, w=16, c=96, k=96, groups=4)).algorithm == "xla"


def test_mobilenet_tuned_plan_end_to_end(monkeypatch):
    """The acceptance path: a MobileNet-style forward runs through a tuned
    plan (cost-model mode) where fused inverted-residual blocks dispatch
    ONE megakernel each (per-layer would have dispatched two or three),
    every unfused depthwise/pointwise site goes through ops.dispatch, and
    the result matches the all-XLA reference."""
    cfg = tiny_variant(get("mobilenet_v2"))
    calls = _spy_algorithms(monkeypatch)  # records (algorithm, params)
    eng = InferenceEngine(cfg)  # algorithm="auto": builds a plan
    plan = eng.plan
    dw_sites = [n for n, s in plan.specs.items() if s.groups > 1]
    pw_sites = [n for n, s in plan.specs.items()
                if s.groups == 1 and s.r == 1]
    assert dw_sites and pw_sites
    # per-conv entries are always planned, even for blocks that fuse —
    # the plan stays deployable on engines without block support
    assert all(plan.choices[n].algorithm == "depthwise" for n in dw_sites)
    assert all(plan.choices[n].algorithm == "pointwise" for n in pw_sites)
    # the strided dense stem runs a strided Pallas kernel, not xla
    assert plan.choices["stem"].algorithm in ("ilpm", "direct")
    # strided depthwise sites are planned, not punted to xla
    assert any(plan.specs[n].stride == 2 for n in dw_sites)
    # the tuner fuses at least one inverted-residual block (acceptance
    # criterion: the expanded tensor never round-trips through HBM there)
    assert plan.block_choices
    assert all(c.algorithm == "fused_inverted_residual"
               for c in plan.block_choices.values())

    img = jax.random.normal(KEY, (32, 32, 3))
    logits = eng.run(img)
    assert logits.shape == (cfg.vocab_size,)
    assert not bool(jnp.isnan(logits).any())
    dispatched = [name for name, _ in calls]
    # each fused block produces exactly ONE dispatch...
    assert (dispatched.count("fused_inverted_residual")
            == len(plan.block_choices))
    # ...and its constituent convs are not dispatched separately; unfused
    # dw/pw sites (and the head projection) still run their tuned kernels
    fused_convs = {f"{b[:-len('.block')]}.{sfx}"
                   for b in plan.block_choices
                   for sfx, _ in plan.block_specs[b].conv_specs()}
    assert dispatched.count("depthwise") == len(
        [n for n in dw_sites if n not in fused_convs])
    assert dispatched.count("pointwise") == len(
        [n for n in pw_sites if n not in fused_convs])

    ref_eng = InferenceEngine(cfg, params=eng.params, algorithm="xla")
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_eng.run(img)),
                               rtol=1e-3, atol=1e-3)
