"""Core engine tests: autotuner, conv2d dispatch, single-image inference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import ConvSpec, InferenceEngine, conv2d, select
from repro.core.autotune import cost_model_select, measured_select
from repro.kernels import ref

KEY = jax.random.key(0)


def test_autotuner_picks_ilpm_on_paper_layers():
    """The cost model must reach the paper's conclusion on its own eval
    layers: ILP-M wins on bandwidth-limited single-image inference."""
    for h, c in [(56, 64), (28, 128), (14, 256)]:
        ch = select(ConvSpec(h=h, w=h, c=c, k=c))
        assert ch.algorithm == "ilpm", (h, c, ch)


def test_autotuner_feasibility_vmem():
    for h, c in [(56, 64), (7, 512)]:
        ch = cost_model_select(ConvSpec(h=h, w=h, c=c, k=c))
        assert ch.vmem <= 16 * 2 ** 20


def test_measured_select_runs():
    spec = ConvSpec(h=8, w=8, c=8, k=8)
    x = jax.random.normal(KEY, (1, 10, 10, 8))
    w = jax.random.normal(KEY, (3, 3, 8, 8))
    ch = measured_select(spec, x, w, repeats=1)
    assert ch.algorithm in ("ilpm", "direct", "im2col", "libdnn", "winograd")


@pytest.mark.parametrize("algorithm",
                         ["auto", "xla", "ilpm", "direct", "winograd"])
def test_conv2d_dispatch(algorithm):
    x = jax.random.normal(KEY, (1, 12, 12, 8))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 3, 8, 16))
    y = conv2d(x, w, algorithm=algorithm)
    gt = ref.conv2d_reference(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                               atol=1e-3)


def test_conv2d_patch_embed_path():
    """Stride-p VALID pxp conv == non-overlapping ILP-M degenerate case."""
    x = jax.random.normal(KEY, (1, 28, 28, 3))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (14, 14, 3, 32))
    y = conv2d(x, w, stride=14, padding="VALID", algorithm="ilpm")
    gt = ref.conv2d_reference(x, w, stride=14, padding="VALID")
    assert y.shape == (1, 2, 2, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                               atol=1e-3)


def test_inference_engine_single_image():
    cfg = tiny_variant(get("resnet18"))
    eng = InferenceEngine(cfg)
    img = jax.random.normal(KEY, (32, 32, 3))
    logits = eng.run(img)
    assert logits.shape == (cfg.vocab_size,)
    assert not bool(jnp.isnan(logits).any())
    reports = eng.traffic_report()
    # every conv site: stem + 2 convs per basic block + the 1x1 projection
    # shortcut of each stage-entry block (stages 1..3 in the tiny config)
    assert len(reports) == 1 + 2 * sum(cfg.extra["blocks"]) + 3
    assert all(r.est_bytes > 0 for r in reports)
    # full backbone coverage: strided sites (stem 7x7/2, stage-entry 3x3/2,
    # 1x1/2 projections) run strided Pallas kernels, never the xla escape
    by_name = {r.name: r for r in reports}
    assert not [r.name for r in reports if r.algorithm == "xla"]
    assert by_name["stem"].algorithm in ("ilpm", "direct")
    assert by_name["s1b0.c1"].algorithm in ("ilpm", "direct")
    assert by_name["s1b0.proj"].algorithm == "pointwise"
    assert by_name["s0b0.c1"].algorithm in ("ilpm", "direct", "libdnn",
                                            "winograd", "im2col")
    assert by_name["s0b0.c1"].params


def test_engine_algorithms_agree():
    cfg = tiny_variant(get("resnet18"))
    img = jax.random.normal(KEY, (32, 32, 3))
    params = InferenceEngine(cfg).params
    outs = {}
    for algo in ("xla", "ilpm", "direct"):
        eng = InferenceEngine(cfg, params=params, algorithm=algo)
        outs[algo] = np.asarray(eng.run(img))
    np.testing.assert_allclose(outs["ilpm"], outs["xla"], rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(outs["direct"], outs["xla"], rtol=1e-3,
                               atol=1e-3)


def test_vit_patch_embed_frontend():
    from repro.models import frontends
    from repro.models.spec import init_params

    cfg = tiny_variant(get("internvl2-26b"))
    p = init_params(frontends.vit_patch_specs(cfg, patch=7), 0, "float32")
    img = jax.random.normal(KEY, (1, 28, 28, 3))
    y = frontends.vit_patch_embed(p, cfg, img, patch=7)
    assert y.shape == (1, 16, cfg.d_model)


def test_audio_stem_frontend():
    from repro.models import frontends
    from repro.models.spec import init_params

    cfg = tiny_variant(get("whisper-base"))
    p = init_params(frontends.audio_stem_specs(cfg, n_mels=16), 0, "float32")
    mel = jax.random.normal(KEY, (1, 32, 16))
    y = frontends.audio_stem(p, cfg, mel)
    assert y.shape == (1, 16, cfg.d_model)  # stride-2 downsample
