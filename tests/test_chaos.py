"""Chaos suite: the serving tier under scripted faults.

Every test drives a deterministic ``FaultInjector`` script through the
real serving stack (no monkeypatched internals) and asserts the issue's
acceptance bar:

  (a) transient dispatch faults are retried — every accepted Future
      resolves, bitwise-equal to the unfaulted engine;
  (b) persistent faults trip the circuit breaker and degrade the engine
      to the xla-only fallback plan — serving continues, and the
      degraded counter surfaces in ``Server.stats()``;
  (c) overload sheds with *typed* rejections (``Overloaded`` at
      admission, ``DeadlineExceeded`` at dequeue, ``CircuitOpen`` from
      the breaker) while accepted requests stay bitwise-correct.
"""
import time
from concurrent.futures import TimeoutError as FutureTimeoutError

import jax
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.serving import (
    CircuitBreaker,
    CircuitOpen,
    DeadlineExceeded,
    EngineCache,
    FaultInjector,
    MicroBatcher,
    Overloaded,
    Rejected,
    RetryPolicy,
    Server,
    StreamSession,
    TransientFailure,
)
from repro.serving.server import Server as ServerClass

KEY = jax.random.key(11)
RESNET = tiny_variant(get("resnet18"))
MOBILENET = tiny_variant(get("mobilenet_v2"))


def _img(i=0, size=32):
    return jax.random.normal(jax.random.fold_in(KEY, i), (size, size, 3))


@pytest.fixture(scope="module")
def engine():
    """One tuned engine shared by the batcher-level chaos tests (builds
    are the expensive part; the batcher never mutates its engine unless
    a degrade hook is wired, and these tests don't wire one)."""
    eng = EngineCache(capacity=2).get(RESNET)
    eng.run(_img())  # warm the jit outside the timed/faulted windows
    return eng


# ----------------------------------------------------------------------
# the injector itself: the script must be exact and reproducible


def test_faultinjector_script_is_deterministic_and_exact():
    fi = (FaultInjector()
          .fail("dispatch", 1, 3)
          .delay("dispatch", 2, seconds=0.5)
          .fail_from("build", 2, error=RuntimeError, message="persistent"))
    assert fi.check("dispatch") == 0.0                   # index 0: clean
    with pytest.raises(TransientFailure):                # index 1: scripted
        fi.check("dispatch")
    assert fi.check("dispatch") == 0.5                   # index 2: delay
    with pytest.raises(TransientFailure):
        fi.check("dispatch")                             # index 3
    assert fi.check("dispatch") == 0.0                   # index 4: clean
    assert fi.check("build") == 0.0 and fi.check("build") == 0.0
    for _ in range(3):                                   # persistent tail
        with pytest.raises(RuntimeError, match="persistent"):
            fi.check("build")
    assert fi.count("dispatch") == 5 and fi.count("build") == 5
    assert fi.log == [("dispatch", 1, "error"), ("dispatch", 2, "delay"),
                      ("dispatch", 3, "error"), ("build", 2, "error"),
                      ("build", 3, "error"), ("build", 4, "error")]
    fi.clear("build")
    assert fi.check("build") == 0.0  # script dropped, counter survived
    assert fi.count("build") == 6


# ----------------------------------------------------------------------
# (a) transient faults: retried, resolved, bitwise


def test_transient_dispatch_fault_retried_bitwise(engine):
    fi = FaultInjector().fail("dispatch", 0)  # first attempt only
    with MicroBatcher(engine, max_batch=1, window_ms=1.0, faults=fi,
                      retry=RetryPolicy(max_retries=2, backoff_s=1e-4)) as b:
        out = b.submit(_img()).result(60.0)
    assert np.array_equal(np.asarray(out), np.asarray(engine.run(_img())))
    st = b.stats()
    assert st["retries"] == 1
    assert st["breaker"]["state"] == "closed"
    assert st["breaker"]["consecutive_failures"] == 0  # success reset it
    assert fi.count("dispatch") == 2  # the retry re-checked the site


def test_transient_chaos_every_accepted_future_resolves(engine):
    """Acceptance (a) end to end: sporadic transient faults across a
    request stream — zero unresolved futures, all outputs bitwise."""
    fi = FaultInjector().fail("dispatch", 1, 4, 5)  # 4,5: double fault
    with MicroBatcher(engine, max_batch=1, window_ms=1.0, faults=fi,
                      retry=RetryPolicy(max_retries=2, backoff_s=1e-4)) as b:
        futs = [(i, b.submit(_img(i))) for i in range(6)]
        outs = [(i, f.result(60.0)) for i, f in futs]
    for i, out in outs:
        assert np.array_equal(np.asarray(out),
                              np.asarray(engine.run(_img(i)))), i
    assert b.stats()["retries"] == 3


def test_retry_exhaustion_surfaces_the_transient_error(engine):
    fi = FaultInjector().fail("dispatch", 0, 1, 2)  # one fault too many
    with MicroBatcher(engine, max_batch=1, window_ms=1.0, faults=fi,
                      retry=RetryPolicy(max_retries=2, backoff_s=1e-4)) as b:
        fut = b.submit(_img())
        with pytest.raises(TransientFailure):
            fut.result(60.0)
    assert b.stats()["retries"] == 2  # both retries were spent


# ----------------------------------------------------------------------
# (b) persistent faults: breaker, degraded mode


def test_persistent_fault_trips_breaker_then_sheds_circuit_open(engine):
    """Without a degrade hook, the breaker's open state is the backstop:
    consecutive failures trip it, then requests shed fast and typed."""
    fi = FaultInjector().fail_from("dispatch", 0, error=RuntimeError,
                                   message="sick tuned kernel")
    with MicroBatcher(engine, max_batch=1, window_ms=1.0, faults=fi,
                      retry=RetryPolicy(max_retries=0),
                      breaker=CircuitBreaker(threshold=3,
                                             reset_s=3600.0)) as b:
        errs = []
        for i in range(5):
            try:
                b.submit(_img(i)).result(60.0)
            except Exception as e:
                errs.append(e)
    assert len(errs) == 5
    assert all(isinstance(e, RuntimeError) for e in errs[:3])
    assert all(isinstance(e, CircuitOpen) for e in errs[3:])
    st = b.stats()
    assert st["breaker"] == {"state": "open", "consecutive_failures": 3,
                             "threshold": 3, "trips": 1}
    assert st["shed"]["breaker"] == 2
    assert fi.count("dispatch") == 3  # open breaker never reaches dispatch


def test_breaker_half_open_probe_cycle():
    t = [0.0]
    br = CircuitBreaker(threshold=2, reset_s=10.0, clock=lambda: t[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert not br.record_failure() is False  # second failure trips
    assert br.state == "open" and not br.allow()
    t[0] = 10.0  # cooldown elapsed
    assert br.state == "half_open"
    assert br.allow()          # exactly one probe
    assert not br.allow()      # concurrent dispatches still shed
    br.record_failure()        # probe failed: re-open for a full cooldown
    assert br.state == "open" and not br.allow()
    t[0] = 20.0
    assert br.allow()
    br.record_success()        # probe succeeded: closed again
    assert br.state == "closed" and br.allow()
    assert br.trips == 1  # the half-open re-open is not a fresh trip


def test_server_persistent_fault_degrades_to_xla_and_keeps_serving():
    """Acceptance (b): the full server path — persistent dispatch faults
    trip the breaker, the batcher swaps in the cache's xla-fallback
    rebuild, serving continues, and ``Server.stats()`` says so."""
    fi = FaultInjector().fail_from("dispatch", 0, error=RuntimeError,
                                   message="persistent kernel fault")
    server = Server(tiny=True, max_batch=1, window_ms=1.0, faults=fi,
                    breaker_threshold=3, retry=RetryPolicy(max_retries=0))
    ref = Server(tiny=True, max_batch=1, window_ms=1.0)
    try:
        ref_out = np.asarray(ref.run("resnet18", _img(), timeout=120.0))
        outs, failures = [], 0
        for _ in range(4):
            try:
                outs.append(server.run("resnet18", _img(), timeout=120.0))
            except RuntimeError:
                failures += 1
        # threshold-1 requests fail; the tripping one degrades and serves
        assert failures == 2 and len(outs) == 2
        st = server.stats()
        assert st["degraded"] == 1
        assert st["cache"]["degraded_keys"], "cache must flag the key"
        (batcher_stats,) = st["networks"].values()
        assert batcher_stats["degraded"] == 1
        assert batcher_stats["breaker"]["state"] == "closed"  # reset
        # the rebuilt engine runs every conv site on the xla escape hatch
        (key,) = server._batchers.keys()
        plan = server._batchers[key].engine.plan
        assert plan.choices and all(c.algorithm == "xla"
                                    for c in plan.choices.values())
        # same params, algorithm route only: outputs match the tuned ref
        for out in outs:
            np.testing.assert_allclose(np.asarray(out), ref_out, atol=1e-4)
    finally:
        server.close()
        ref.close()


def test_engine_cache_build_transient_fault_is_retried():
    fi = FaultInjector().fail("build", 0)
    cache = EngineCache(capacity=2, faults=fi,
                        retry=RetryPolicy(max_retries=2, backoff_s=1e-4))
    eng = cache.get(RESNET)
    assert np.asarray(eng.run(_img())).ndim == 1
    assert cache.build_retries == 1
    assert cache.degraded == 0
    assert fi.count("build") == 2


def test_engine_cache_plan_deploy_failure_falls_back_to_xla():
    """A rebuild that persistently fails while deploying a cached plan
    must come up degraded (xla-only plan) rather than fail the key."""
    fi = FaultInjector()
    cache = EngineCache(capacity=1, faults=fi,
                        retry=RetryPolicy(max_retries=1, backoff_s=1e-4))
    cache.get(RESNET)        # tunes + caches the plan
    cache.get(MOBILENET)     # capacity 1: evicts the resnet engine
    assert cache.evictions == 1
    fi.fail_from("plan_deploy", 0, error=RuntimeError,
                 message="deploy rejected")
    eng = cache.get(RESNET)  # rebuild deploys the cached plan -> fault
    assert all(c.algorithm == "xla" for c in eng.plan.choices.values())
    assert np.asarray(eng.run(_img())).ndim == 1
    assert cache.degraded == 1
    assert cache.stats()["degraded_keys"]


# ----------------------------------------------------------------------
# (c) overload: typed shedding, accepted requests stay correct


def test_overload_sheds_typed_and_accepted_stay_bitwise(engine):
    """2x+-capacity burst against a bounded queue: the excess is rejected
    with ``Overloaded`` at admission, and every accepted request resolves
    bitwise-equal to the unfaulted engine."""
    fi = FaultInjector().delay_from("dispatch", 0, seconds=0.1)
    with MicroBatcher(engine, max_batch=1, window_ms=0.5, max_queue=2,
                      faults=fi) as b:
        accepted, rejected = [], 0
        for i in range(10):  # burst far beyond queue + in-flight capacity
            try:
                accepted.append((i, b.submit(_img(i))))
            except Overloaded:
                rejected += 1
        results = [(i, f.result(120.0)) for i, f in accepted]
    assert rejected >= 1 and len(accepted) + rejected == 10
    assert b.stats()["shed"]["overload"] == rejected
    for i, out in results:  # faults delay, never corrupt
        assert np.array_equal(np.asarray(out),
                              np.asarray(engine.run(_img(i)))), i


def test_expired_requests_shed_at_dequeue_before_compute(engine):
    fi = FaultInjector().delay_from("dispatch", 0, seconds=0.15)
    with MicroBatcher(engine, max_batch=1, window_ms=0.5, deadline_ms=40.0,
                      faults=fi) as b:
        first = b.submit(_img(0))     # dequeued fresh, holds the loop
        late = [b.submit(_img(i)) for i in (1, 2)]  # expire while queued
        assert first.result(120.0) is not None
        for f in late:
            with pytest.raises(DeadlineExceeded, match="shed at dequeue"):
                f.result(120.0)
    assert b.stats()["shed"]["deadline"] == 2
    # the shed requests never reached dispatch: only request 0 was checked
    assert fi.count("dispatch") == 1


def test_cancelled_request_sheds_at_dequeue(engine):
    fi = FaultInjector().delay_from("dispatch", 0, seconds=0.15)
    with MicroBatcher(engine, max_batch=1, window_ms=0.5, faults=fi) as b:
        first = b.submit(_img(0))
        req = b.submit_request(_img(1))  # queued behind the slow dispatch
        req.cancel()
        first.result(120.0)
        with pytest.raises(DeadlineExceeded, match="cancelled"):
            req.future.result(120.0)
    assert b.stats()["shed"]["cancelled"] == 1
    assert fi.count("dispatch") == 1


def test_server_run_timeout_cancels_the_queued_request():
    """``Server.run(timeout=...)`` must actually cancel on timeout: the
    timed-out request is shed at dequeue instead of computed for nobody."""
    fi = FaultInjector().delay_from("dispatch", 0, seconds=0.3)
    server = Server(tiny=True, max_batch=1, window_ms=0.5, faults=fi)
    try:
        server.warm("resnet18")
        blocker = server.submit("resnet18", _img(0))  # occupies the loop
        with pytest.raises(FutureTimeoutError):
            server.run("resnet18", _img(1), timeout=0.02)
        blocker.result(120.0)
        deadline = time.perf_counter() + 30.0
        while time.perf_counter() < deadline:  # the shed happens at the
            (bs,) = server.stats()["networks"].values()  # loop's dequeue
            if bs["shed"]["cancelled"]:
                break
            time.sleep(0.01)
        assert bs["shed"]["cancelled"] == 1
        assert fi.count("dispatch") == 1  # never dispatched the dead one
    finally:
        server.close()


def test_server_close_is_idempotent_and_rejects_typed():
    server = Server(tiny=True)
    server.close()
    server.close()  # second close: no-op, no deadlock
    with pytest.raises(Overloaded):
        server.submit("resnet18", _img())
    with pytest.raises(Rejected):  # the typed hierarchy callers catch
        server.open_stream("resnet18", sim_compute_s=0.01)
    # Overloaded is still a RuntimeError: pre-resilience callers that
    # caught RuntimeError keep working unchanged
    with pytest.raises(RuntimeError):
        server.submit("resnet18", _img())


def test_stats_key_includes_dtype():
    """fp32 and bf16 variants of one network must not collide in
    ``Server.stats()`` (the old key was (network, input_size) only)."""
    key32 = ("resnet18-tiny", 32, "cpu", "float32", "float32")
    key16 = ("resnet18-tiny", 32, "cpu", "bfloat16", "bfloat16")
    mixed = ("resnet18-tiny", 32, "cpu", "float32", "bfloat16")
    assert ServerClass._stats_key(key32) == "resnet18-tiny/32/float32"
    assert ServerClass._stats_key(key16) == "resnet18-tiny/32/bfloat16"
    assert ServerClass._stats_key(mixed) == \
        "resnet18-tiny/32/float32/params=bfloat16"
    assert len({ServerClass._stats_key(k)
                for k in (key32, key16, mixed)}) == 3


# ----------------------------------------------------------------------
# streams under chaos (simulated clock: exact, repeatable accounting)


def _sim_stream(cache, faults, n_frames=6, sim_compute_s=0.008):
    session = StreamSession(cache.lease(RESNET), fps=30.0,
                            sim_compute_s=sim_compute_s, name="chaos",
                            faults=faults)
    frames = [session.submit_frame(_img(i)) for i in range(n_frames)]
    session.close()
    return session, frames


def test_stream_frame_fault_settles_frame_and_stream_survives():
    cache = EngineCache(capacity=2)
    fi = FaultInjector().fail("frame", 1, error=RuntimeError,
                              message="frame executor fault")
    session, frames = _sim_stream(cache, fi)
    with pytest.raises(RuntimeError, match="frame executor fault"):
        frames[1].future.result(60.0)
    assert frames[1].missed and not frames[1].dropped
    for f in frames[:1] + frames[2:]:  # every other frame resolved
        assert np.asarray(f.future.result(60.0)).ndim == 1
    st = session.stats()
    assert st["frames"] == len(frames)
    assert st["deadline_misses"] == 1


def test_stream_injected_latency_spike_misses_deterministically():
    """A scripted latency spike joins the simulated compute charge as
    pure arithmetic: the same script yields the exact same per-frame
    done-times and miss set on every run."""
    def run():
        cache = EngineCache(capacity=2)
        fi = FaultInjector().delay("frame", 2, seconds=0.05)
        session, frames = _sim_stream(cache, fi)
        return session.stats(), [(f.done, f.missed) for f in frames]

    stats_a, ledger_a = run()
    stats_b, ledger_b = run()
    assert ledger_a == ledger_b  # bit-exact repeatability
    assert stats_a["deadline_misses"] == stats_b["deadline_misses"] == 1
    done, missed = ledger_a[2]
    assert missed
    # the spike is charged arithmetically: done == arrival + compute + 0.05
    period = 1.0 / 30.0
    assert done == pytest.approx(2 * period + 0.008 + 0.05, abs=1e-12)
    assert all(not m for (_, m) in ledger_a[:2] + ledger_a[3:])
