"""Per-kernel correctness sweeps: every Pallas conv kernel (interpret mode)
against the lax ground truth and its own jnp structural reference, across
shapes, dtypes, and block parameters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)

SHAPES = [
    # (B, H, W, C, K) — includes the paper's ResNet layers (Table 2)
    (1, 56, 56, 64, 64),    # conv2.x
    (1, 28, 28, 128, 128),  # conv3.x
    (1, 14, 14, 256, 256),  # conv4.x
    (1, 8, 8, 96, 160),
    (2, 12, 10, 16, 24),    # batch > 1
    (1, 7, 9, 13, 40),      # odd dims, ragged channel counts
    (1, 6, 6, 8, 8),
]

ALGOS = ["ilpm", "direct", "im2col", "libdnn", "winograd"]


def _mk(b, h, w, c, k, dtype, r=3, s=3):
    x = jax.random.normal(KEY, (b, h, w, c), dtype)
    wgt = jax.random.normal(jax.random.fold_in(KEY, 7), (r, s, c, k), dtype)
    return x, wgt


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("algo", ALGOS)
def test_kernel_vs_ground_truth(shape, algo):
    b, h, w, c, k = shape
    if algo == "winograd" and (h % 2 or w % 2):
        pytest.skip("winograd F(2,3) needs even output dims")
    x, wgt = _mk(b, h, w, c, k, jnp.float32)
    gt = ref.conv2d_reference(x, wgt)
    xp = ref.pad_same(x, 3, 3)
    y = ops.ALGORITHMS[algo](xp, wgt, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(gt, np.float32),
        rtol=2e-4, atol=2e-4 * float(jnp.abs(gt).max()))


@pytest.mark.parametrize("algo", ALGOS)
def test_kernel_vs_structural_ref(algo):
    """Pallas kernel must agree with the *algorithm's* jnp reference."""
    x, wgt = _mk(1, 14, 14, 32, 48, jnp.float32)
    xp = ref.pad_same(x, 3, 3)
    y_pl = ops.ALGORITHMS[algo](xp, wgt, impl="pallas")
    y_ref = ops.ALGORITHMS[algo](xp, wgt, impl="jnp")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=2e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("algo", ALGOS)
def test_kernel_dtypes(algo, dtype):
    x, wgt = _mk(1, 14, 14, 32, 64, dtype)
    gt = ref.conv2d_reference(x.astype(jnp.float32), wgt.astype(jnp.float32))
    xp = ref.pad_same(x, 3, 3)
    y = ops.ALGORITHMS[algo](xp, wgt, impl="pallas").astype(jnp.float32)
    rel = float(jnp.abs(y - gt).max() / (jnp.abs(gt).max() + 1e-9))
    assert rel < _tol(dtype), rel


@pytest.mark.parametrize("block_k", [32, 64, 128, 512])
def test_ilpm_block_sweep(block_k):
    x, wgt = _mk(1, 10, 10, 16, 96, jnp.float32)
    xp = ref.pad_same(x, 3, 3)
    y = ops.ilpm(xp, wgt, impl="pallas", block_k=block_k)
    gt = ref.conv2d_reference(x, wgt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                               atol=1e-3)


@pytest.mark.parametrize("block_h", [2, 4, 8, 16])
def test_direct_block_sweep(block_h):
    x, wgt = _mk(1, 13, 11, 16, 32, jnp.float32)  # 13 % block_h != 0 paths
    xp = ref.pad_same(x, 3, 3)
    y = ops.direct(xp, wgt, impl="pallas", block_h=block_h)
    gt = ref.conv2d_reference(x, wgt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                               atol=1e-3)


@pytest.mark.parametrize("rs", [(1, 1), (3, 3), (5, 5), (3, 5)])
def test_filter_size_sweep(rs):
    r, s = rs
    x, wgt = _mk(1, 12, 12, 8, 16, jnp.float32, r=r, s=s)
    gt = ref.conv2d_reference(x, wgt)
    xp = ref.pad_same(x, r, s)
    for algo in ("ilpm", "direct", "libdnn", "im2col"):
        y = ops.ALGORITHMS[algo](xp, wgt, impl="pallas")
        np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                                   atol=1e-3, err_msg=algo)


@pytest.mark.parametrize("block_l", [16, 64, 512])
@pytest.mark.parametrize("k", [2, 4])
def test_causal_conv1d_sweep(block_l, k):
    x = jax.random.normal(KEY, (2, 75, 24))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, 24))
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (24,))
    y = ops.causal_conv1d(x, w, b, impl="pallas", block_l=block_l)
    y_ref = ref.causal_conv1d(x, w, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-5)


def test_causal_conv1d_is_causal():
    """Output at t must not depend on inputs after t."""
    x = jax.random.normal(KEY, (1, 32, 8))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 8))
    y1 = ops.causal_conv1d(x, w, impl="pallas", block_l=16)
    x2 = x.at[:, 20:].set(99.0)
    y2 = ops.causal_conv1d(x2, w, impl="pallas", block_l=16)
    np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                               rtol=1e-6)


def test_winograd_filter_transform_offline():
    """u precomputed offline (inference, paper §5.2) == inline transform."""
    x, wgt = _mk(1, 8, 8, 8, 8, jnp.float32)
    xp = ref.pad_same(x, 3, 3)
    u = ref.winograd_filter_transform(wgt)
    from repro.kernels.winograd_conv import winograd_conv

    y1 = winograd_conv(xp, wgt, u=u, interpret=True)
    y2 = winograd_conv(xp, wgt, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
