"""Tuning-plan subsystem tests: JSON round-trip, per-layer dispatch,
cost-model vs measured agreement, and whole-package import health."""
import importlib
import pkgutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine, TuningPlan, build_plan
from repro.core.autotune import (Choice, ConvSpec, cost_model_select,
                                 measured_select)
from conftest import spy_algorithms as _spy_algorithms
from repro.kernels import ops

KEY = jax.random.key(0)


def test_plan_json_roundtrip(tmp_path):
    specs = [("a", ConvSpec(h=8, w=8, c=16, k=16)),
             ("b", ConvSpec(h=4, w=4, c=32, k=32)),
             ("stem", ConvSpec(h=32, w=32, c=3, k=64, r=7, s=7, stride=2)),
             # grouped sites: depthwise (strided) + pointwise 1x1
             ("dw", ConvSpec(h=8, w=8, c=32, k=32, groups=32, stride=2)),
             ("pw", ConvSpec(h=8, w=8, c=32, k=64, r=1, s=1))]
    plan = build_plan(specs, mode="cost_model")
    back = TuningPlan.from_json(plan.to_json())
    assert back.mode == plan.mode
    assert back.specs == plan.specs
    assert back.choices == plan.choices

    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = TuningPlan.load(path)
    assert loaded.choices == plan.choices
    assert loaded.specs == plan.specs


def test_plan_rejects_unknown_version():
    with pytest.raises(ValueError):
        TuningPlan.from_json('{"version": 999, "mode": "x", "layers": {}}')


def test_per_layer_dispatch_reaches_chosen_kernels(monkeypatch):
    """Two layers pinned to *different* algorithms with explicit kernel
    params must each reach their own kernel — the per-layer dispatch the
    engine's plan threading exists to provide."""
    cfg = tiny_variant(get("resnet18"))
    plan = TuningPlan(mode="cost_model")
    plan.specs["s0b0.c1"] = ConvSpec(h=8, w=8, c=64, k=64)
    plan.choices["s0b0.c1"] = Choice("direct", (("block_h", 4),), 0.0, 1, 1, 1)
    plan.specs["s0b0.c2"] = ConvSpec(h=8, w=8, c=64, k=64)
    plan.choices["s0b0.c2"] = Choice("ilpm", (("block_k", 64),), 0.0, 1, 1, 1)

    calls = _spy_algorithms(monkeypatch)
    eng = InferenceEngine(cfg, plan=plan)
    eng.run(jax.random.normal(KEY, (32, 32, 3)))
    assert ("direct", (("block_h", 4),)) in calls
    assert ("ilpm", (("block_k", 64),)) in calls


def test_engine_auto_plan_drives_dispatch(monkeypatch, tmp_path):
    """algorithm='auto' jits a forward where each layer runs its tuned
    algorithm with its tuned params, and the plan survives save/load."""
    cfg = tiny_variant(get("resnet18"))
    eng = InferenceEngine(cfg)  # algorithm="auto": builds a plan
    plan = eng.plan
    assert plan is not None

    # the plan is genuinely per-layer: >= 2 distinct algorithms (3x3 sites
    # pick a dense kernel, 1x1 projections pick pointwise), and the tuned
    # kernel params differ across layers (block_k tracks K)
    assert len(set(plan.algorithms().values())) >= 2
    tuned = {n: c for n, c in plan.choices.items() if c.algorithm != "xla"}
    assert len(tuned) >= 2
    assert len({c.params for c in tuned.values()}) >= 2
    # the tuner fuses the residual add into each block's final conv
    assert plan.block_choices
    assert all(c.algorithm == "fused_residual_conv"
               for c in plan.block_choices.values())

    calls = _spy_algorithms(monkeypatch)
    img = jax.random.normal(KEY, (32, 32, 3))
    logits = eng.run(img)

    # the dispatched kernels match the plan exactly: one call per planned
    # non-xla site with that site's tuned params, except that each fused
    # block replaces its final per-conv dispatch with ONE block dispatch
    fused_convs = {f"{b[:-len('.block')]}.{sfx}"
                   for b in plan.block_choices
                   for sfx, _ in plan.block_specs[b].conv_specs()}
    expected = sorted(
        [(c.algorithm, c.params) for n, c in tuned.items()
         if n not in fused_convs]
        + [(c.algorithm, c.params) for c in plan.block_choices.values()])
    assert sorted(calls) == expected

    # tune-once / deploy-many: JSON round-trip, same dispatch, same logits
    path = tmp_path / "plan.json"
    eng.save_plan(path)
    loaded = TuningPlan.load(path)
    assert loaded.choices == plan.choices
    assert loaded.block_choices == plan.block_choices
    assert loaded.block_specs == plan.block_specs

    calls.clear()
    eng2 = InferenceEngine(cfg, params=eng.params, plan=str(path))
    logits2 = eng2.run(img)
    assert sorted(calls) == expected
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(logits),
                               rtol=1e-6, atol=1e-6)


def test_plan_validation_rejects_wrong_network(tmp_path):
    """A plan tuned for one input size must not silently deploy onto a
    network with different conv geometry."""
    cfg = tiny_variant(get("resnet18"))
    eng = InferenceEngine(cfg)
    path = tmp_path / "plan.json"
    eng.save_plan(path)
    full = get("resnet18")  # img=224: same layer names, different shapes
    with pytest.raises(ValueError, match="different network"):
        InferenceEngine(full, params=eng.params, plan=str(path))


def test_bottleneck_plan_sites_and_widths():
    """Bottleneck stages tune their 3x3 at the bottleneck width (cout/4)
    and every 1x1 (reduce/expand/projection) is a planned pointwise site —
    the spec enumeration walks the real geometry."""
    cfg = tiny_variant(get("resnet50"))
    eng = InferenceEngine(cfg)
    plan = eng.plan
    assert set(plan.specs) == {"stem"} | {
        f"s{si}b0.{c}" for si in range(4)
        for c in ("proj", "c1", "c2", "c3")}
    assert (plan.specs["s0b0.c2"].c, plan.specs["s0b0.c2"].k) == (64, 64)
    assert (plan.specs["s3b0.c2"].c, plan.specs["s3b0.c2"].k) == (512, 512)
    assert plan.specs["s1b0.c2"].stride == 2  # stage entry carries stride
    # 1x1 sites: reduce/expand widths and the strided projection shortcut
    assert (plan.specs["s0b0.c1"].c, plan.specs["s0b0.c1"].k) == (64, 64)
    assert (plan.specs["s0b0.c3"].c, plan.specs["s0b0.c3"].k) == (64, 256)
    assert plan.specs["s1b0.proj"].stride == 2
    assert plan.choices["s0b0.c1"].algorithm == "pointwise"
    assert plan.choices["s1b0.proj"].algorithm == "pointwise"
    logits = eng.run(jax.random.normal(KEY, (32, 32, 3)))
    assert logits.shape == (cfg.vocab_size,)
    assert not bool(jnp.isnan(logits).any())


def test_cost_model_and_measured_agree_on_small_spec():
    """Both tuning modes reach the paper's conclusion (ILP-M) on a layer
    big enough that real work, not interpreter dispatch, dominates."""
    spec = ConvSpec(h=32, w=32, c=128, k=128)
    cm = cost_model_select(spec)
    ms = measured_select(spec, repeats=5)
    assert cm.algorithm == ms.algorithm == "ilpm"


def test_measured_select_warns_on_failed_candidate(monkeypatch, caplog):
    import logging

    def boom(x, w, *, impl="auto", **params):
        raise RuntimeError("kaboom")

    monkeypatch.setitem(ops.ALGORITHMS, "im2col", boom)
    with caplog.at_level(logging.WARNING, logger="repro.core.autotune"):
        ch = measured_select(ConvSpec(h=4, w=4, c=4, k=4), repeats=1)
    assert ch.algorithm != "im2col"
    assert "im2col" in caplog.text


def test_import_every_repro_module():
    """Regression net for API drift (e.g. jax.shard_map moving): every
    module in the package must import cleanly."""
    import repro

    failures = []
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        try:
            importlib.import_module(mod.name)
        except Exception as e:  # pragma: no cover - failure path
            failures.append((mod.name, repr(e)))
    assert not failures, failures
