"""Strided-kernel + fused-epilogue coverage: strided ilpm/direct/pointwise
sweeps against the lax ground truth, epilogue-fusion parity (conv+BN+act in
one kernel pass vs the unfused reference), depthwise channel multipliers,
whole-backbone plan coverage (zero xla choices for dense conv sites), and
the once-per-engine Winograd filter-transform cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import ConvSpec, InferenceEngine, TuningPlan, conv2d
from repro.core.autotune import Choice, cost_model_select, tunable
from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _mk(b, h, w, c, k, r=3, s=3):
    x = jax.random.normal(KEY, (b, h, w, c))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 7), (r, s, c, k))
    return x, wgt


# ---------------------------------------------------------------------
# strided dense kernels

# (H, W, C, K, R) — odd H/W, the stem's 7x7, ragged channels
STRIDED_CASES = [
    (16, 16, 8, 16, 3),
    (13, 11, 8, 24, 3),     # odd dims: SAME padding asymmetry under stride
    (32, 32, 3, 64, 7),     # the ResNet stem shape class
    (15, 9, 5, 13, 7),      # odd everything
    (8, 8, 16, 130, 3),     # K > one lane block, ragged
]


@pytest.mark.parametrize("case", STRIDED_CASES, ids=str)
@pytest.mark.parametrize("algo", ["ilpm", "direct"])
def test_strided_dense_kernel_vs_ground_truth(case, algo):
    h, w, c, k, r = case
    x, wgt = _mk(1, h, w, c, k, r=r, s=r)
    gt = ref.conv2d_reference(x, wgt, stride=2)
    xp = ref.pad_same(x, r, r, stride=2)
    for impl in ("pallas", "jnp"):
        y = ops.ALGORITHMS[algo](xp, wgt, impl=impl, stride=2)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(gt), rtol=2e-4,
            atol=2e-4 * float(jnp.abs(gt).max()), err_msg=f"{algo}/{impl}")


@pytest.mark.parametrize("block", [2, 4, 8])
def test_strided_direct_block_sweep(block):
    x, wgt = _mk(1, 13, 11, 8, 16)
    gt = ref.conv2d_reference(x, wgt, stride=2)
    xp = ref.pad_same(x, 3, 3, stride=2)
    y = ops.direct(xp, wgt, impl="pallas", stride=2, block_h=block)
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                               atol=1e-3)


@pytest.mark.parametrize("hw", [(16, 16), (13, 11), (7, 7)])
def test_strided_pointwise_vs_ground_truth(hw):
    h, w = hw
    x = jax.random.normal(KEY, (1, h, w, 24))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 1, 24, 40))
    gt = ref.conv2d_reference(x, wgt, stride=2)
    for impl in ("pallas", "jnp"):
        y = ops.pointwise(x, wgt, impl=impl, stride=2)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                                   atol=1e-3, err_msg=impl)


def test_strided_conv2d_routes_to_kernels():
    """conv2d at stride 2 dispatches the strided kernels (and redirects
    the stride-1-only algorithms to ilpm) — full-precision vs lax."""
    x, wgt = _mk(1, 14, 14, 8, 16)
    gt = ref.conv2d_reference(x, wgt, stride=2)
    for algo in ("auto", "ilpm", "direct", "winograd", "im2col"):
        y = conv2d(x, wgt, stride=2, algorithm=algo)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=2e-4,
                                   atol=1e-3, err_msg=algo)


def test_tunable_covers_strided_classes():
    assert tunable(ConvSpec(h=16, w=16, c=8, k=16, stride=2))
    assert tunable(ConvSpec(h=32, w=32, c=3, k=64, r=7, s=7, stride=2))
    assert tunable(ConvSpec(h=16, w=16, c=8, k=16, r=1, s=1, stride=2))
    assert not tunable(ConvSpec(h=16, w=16, c=8, k=16, stride=4))
    # strided candidates enumerate only the in-kernel-downsampling families
    ch = cost_model_select(ConvSpec(h=56, w=56, c=64, k=64, stride=2))
    assert ch.algorithm in ("ilpm", "direct")


# ---------------------------------------------------------------------
# fused epilogue parity

EPILOGUE_ALGOS = ["ilpm", "direct", "im2col", "libdnn", "winograd"]


@pytest.mark.parametrize("algo", EPILOGUE_ALGOS)
@pytest.mark.parametrize("act", [None, "relu", "relu6"])
def test_dense_epilogue_fusion_parity(algo, act):
    """conv+scale+bias+act fused in-kernel == unfused reference (fp32)."""
    x, wgt = _mk(1, 12, 12, 8, 16)
    sc = jax.random.normal(jax.random.fold_in(KEY, 11), (16,))
    bi = jax.random.normal(jax.random.fold_in(KEY, 12), (16,))
    xp = ref.pad_same(x, 3, 3)
    want = ref.apply_epilogue(ref.conv2d_reference(x, wgt), scale=sc,
                              bias=bi, act=act)
    for impl in ("pallas", "jnp"):
        y = ops.ALGORITHMS[algo](xp, wgt, impl=impl, scale=sc, bias=bi,
                                 act=act)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(want), rtol=2e-4,
            atol=2e-4 * float(jnp.abs(want).max() + 1),
            err_msg=f"{algo}/{impl}")


def test_grouped_epilogue_fusion_parity():
    x = jax.random.normal(KEY, (1, 10, 10, 12))
    dw = jax.random.normal(jax.random.fold_in(KEY, 5), (3, 3, 1, 12))
    pw = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 1, 12, 20))
    for w, k, algo, gt in [
            (dw, 12, "depthwise", ref.conv2d_reference(x, dw, groups=12)),
            (pw, 20, "pointwise", ref.conv2d_reference(x, pw))]:
        sc = jax.random.normal(jax.random.fold_in(KEY, k), (k,))
        bi = jax.random.normal(jax.random.fold_in(KEY, k + 1), (k,))
        xin = ref.pad_same(x, 3, 3) if algo == "depthwise" else x
        want = ref.apply_epilogue(gt, scale=sc, bias=bi, act="relu6")
        y = ops.ALGORITHMS[algo](xin, w, impl="pallas", scale=sc, bias=bi,
                                 act="relu6")
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-4, atol=1e-3, err_msg=algo)


def test_conv2d_fused_epilogue_strided():
    """The conv2d entry point threads (scale, bias, act) through dispatch
    at strided sites too — the stem's conv+BN+ReLU in one call."""
    x, wgt = _mk(1, 32, 32, 3, 64, r=7, s=7)
    sc = jax.random.normal(jax.random.fold_in(KEY, 21), (64,))
    bi = jax.random.normal(jax.random.fold_in(KEY, 22), (64,))
    want = ref.apply_epilogue(ref.conv2d_reference(x, wgt, stride=2),
                              scale=sc, bias=bi, act="relu")
    for algo in ("auto", "ilpm", "direct", "xla"):
        y = conv2d(x, wgt, stride=2, algorithm=algo, scale=sc, bias=bi,
                   act="relu")
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-4, atol=1e-3, err_msg=algo)


# ---------------------------------------------------------------------
# depthwise channel multiplier > 1

@pytest.mark.parametrize("mult", [2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_depthwise_channel_multiplier_vs_ground_truth(mult, stride):
    c = 10
    x = jax.random.normal(KEY, (1, 11, 13, c))
    wgt = jax.random.normal(jax.random.fold_in(KEY, 9), (3, 3, 1, mult * c))
    gt = ref.conv2d_reference(x, wgt, stride=stride, groups=c)
    xp = ref.pad_same(x, 3, 3, stride=stride)
    for impl in ("pallas", "jnp"):
        y = ops.depthwise(xp, wgt, impl=impl, stride=stride)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-4,
                                   atol=1e-3, err_msg=impl)
    # and through the public entry point (groups detected from shapes)
    y = conv2d(x, wgt, stride=stride, algorithm="auto")
    np.testing.assert_allclose(np.asarray(y), np.asarray(gt), rtol=1e-4,
                               atol=1e-3)


def test_convspec_channel_multiplier():
    x = jax.random.normal(KEY, (1, 8, 8, 12))
    wgt = jax.random.normal(KEY, (3, 3, 1, 24))  # M = 2
    spec = ConvSpec.from_tensors(x, wgt, 1)
    assert (spec.c, spec.k, spec.groups) == (12, 24, 12)
    assert spec.depthwise and spec.channel_multiplier == 2
    assert tunable(spec)
    assert cost_model_select(spec).algorithm == "depthwise"


# ---------------------------------------------------------------------
# whole-backbone coverage + the cached Winograd transform

@pytest.mark.parametrize("net", ["resnet18", "resnet50"])
def test_tuned_resnet_plan_has_no_xla_dense_sites(net):
    """Acceptance: a tuned ResNet plan contains zero 'xla' choices — stem,
    strided stage entries, and every 1x1 included — and the fused forward
    matches the unfused all-XLA reference."""
    cfg = tiny_variant(get(net))
    eng = InferenceEngine(cfg)
    algos = eng.plan.algorithms()
    xla_sites = [n for n, a in algos.items() if a == "xla"]
    assert not xla_sites, xla_sites
    # strided + 1x1 sites resolve to real kernel families
    assert algos["stem"] in ("ilpm", "direct")
    assert algos["s1b0.proj"] == "pointwise"
    # block sites resolve to the fused family too — no block ever
    # regresses to an escape hatch (select_block returns None, never xla)
    assert eng.plan.block_choices
    assert all(c.algorithm == "fused_residual_conv"
               for c in eng.plan.block_choices.values())
    img = jax.random.normal(KEY, (32, 32, 3))
    out = eng.run(img)
    want = InferenceEngine(cfg, params=eng.params, algorithm="xla").run(img)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3,
                               atol=1e-3)


def test_winograd_filter_transform_cached_once_per_engine(monkeypatch):
    """U = G g G^T is computed exactly once per winograd site at engine
    build, never per forward (weights are frozen at inference)."""
    calls = {"n": 0}
    inner = ref.winograd_filter_transform

    def counting(w):
        calls["n"] += 1
        return inner(w)

    monkeypatch.setattr(ref, "winograd_filter_transform", counting)

    cfg = tiny_variant(get("resnet18"))
    # pin one even-sized stride-1 3x3 site to winograd; the engine must
    # transform its filters exactly once at build time
    plan = TuningPlan(mode="cost_model")
    plan.specs["s0b0.c1"] = ConvSpec(h=8, w=8, c=64, k=64)
    plan.choices["s0b0.c1"] = Choice("winograd", (), 0.0, 1, 1, 1)
    eng = InferenceEngine(cfg, plan=plan)
    assert calls["n"] == 1
    assert set(eng.winograd_u) == {"s0b0.c1"}

    img = jax.random.normal(KEY, (32, 32, 3))
    out = eng.run(img)
    eng.run(img)
    assert calls["n"] == 1  # forwards reuse the cached U

    want = InferenceEngine(cfg, params=eng.params, algorithm="xla").run(img)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3,
                               atol=1e-3)
