"""The continuous-batching front door: mid-flight admission, the
cross-network device scheduler, the options-object API (+ deprecation
shim), and the unified ``Ticket`` handle.

Batcher-level tests drive ``MicroBatcher`` with stub engines (dispatch
timing is the subject, not convolution), so they are fast and
deterministic; API tests use real tiny networks where numerics matter.
"""
import threading
import time
import warnings

import numpy as np
import pytest

from repro.serving import (
    DeviceScheduler,
    MicroBatcher,
    Overloaded,
    RequestOptions,
    Server,
    ServingOptions,
    Ticket,
)


class FakeEngine:
    """Engine stub: echoes per-image sums so results are checkable, and
    sleeps a configurable service time so tests control dispatch
    duration."""

    def __init__(self, service_s=0.0):
        self.service_s = service_s
        self.batches = []  # batch size per dispatch, in dispatch order

    def run(self, image):
        self.batches.append(1)
        if self.service_s:
            time.sleep(self.service_s)
        return np.asarray(image).sum(keepdims=True)

    def run_batch(self, images):
        images = np.asarray(images)
        self.batches.append(images.shape[0])
        if self.service_s:
            time.sleep(self.service_s)
        return images.sum(axis=(1, 2, 3), keepdims=True)


def _img(v):
    return np.full((4, 4, 3), float(v), dtype=np.float32)


# ---------------------------------------------------------------------------
# mid-flight admission (the continuous-batching core)


def test_requests_join_forming_batch_during_dispatch():
    """Requests arriving while the loop is busy dispatching coalesce into
    ONE next batch instead of one window each — the mid-flight admission
    the deadline-window design couldn't do."""
    engine = FakeEngine(service_s=0.15)
    with MicroBatcher(engine, max_batch=8, window_ms=0.0) as b:
        t0 = b.submit(_img(0))  # dispatches alone (window 0)
        time.sleep(0.05)        # loop is now inside the 0.15s dispatch
        rest = [b.submit(_img(i + 1)) for i in range(3)]
        t0.result(timeout=10)
        for t in rest:
            t.result(timeout=10)
    assert engine.batches == [1, 4]  # 3 coalesced, padded to the 4-bucket
    assert [d["batch"] for d in b.dispatches] == [1, 3]
    assert b.stats()["joined_forming"] == 2  # 2 of the 3 joined a form
    # numerics unchanged by coalescing: each result is its own image sum
    assert rest[1].result()[0] == pytest.approx(4 * 4 * 3 * 2.0)


def test_window_anchored_at_oldest_arrival():
    """The batching window is measured from the OLDEST pending request's
    arrival, not from when the loop dequeues: a late joiner rides out the
    remainder of the first request's window instead of restarting it."""
    engine = FakeEngine()
    with MicroBatcher(engine, max_batch=8, window_ms=200.0) as b:
        t0 = time.perf_counter()
        first = b.submit(_img(1))
        time.sleep(0.12)  # join mid-window
        late = b.submit(_img(2))
        first.result(timeout=10)
        late.result(timeout=10)
        wall = time.perf_counter() - t0
    # one shared dispatch at ~t0+0.2; a window restarted at the late
    # join (or at dequeue) would push wall past ~0.32
    assert engine.batches == [2]
    assert wall < 0.30, f"window restarted: wall {wall:.3f}s"
    assert b.stats()["dispatch_causes"]["window"] == 1
    assert b.stats()["joined_forming"] == 1


def test_mid_flight_batch_respects_max_batch():
    """The forming batch never exceeds max_batch: overflow requests roll
    into the following dispatch."""
    engine = FakeEngine(service_s=0.15)
    with MicroBatcher(engine, max_batch=2, window_ms=0.0) as b:
        first = b.submit(_img(0))
        time.sleep(0.05)
        rest = [b.submit(_img(i)) for i in range(3)]
        for t in [first, *rest]:
            t.result(timeout=10)
    assert engine.batches == [1, 2, 1]


def test_bitwise_equal_to_sequential_with_mid_flight_admission():
    """The acceptance contract survives the rework: coalesced results are
    bitwise-equal to sequential engine.run, even when requests joined the
    batch mid-flight."""
    import jax

    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine

    engine = InferenceEngine(tiny_variant(get("resnet18")))
    key = jax.random.key(7)
    imgs = [jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))
            for i in range(5)]
    seq = [np.asarray(engine.run(im)) for im in imgs]
    with MicroBatcher(engine, max_batch=4, window_ms=40.0) as b:
        tickets = []
        for im in imgs:  # trickle in so later ones join mid-flight
            tickets.append(b.submit(im))
            time.sleep(0.005)
        got = [np.asarray(t.result(timeout=120)) for t in tickets]
    for g, s in zip(got, seq):
        np.testing.assert_array_equal(g, s)
    assert b.stats()["joined_forming"] >= 1  # coalescing actually happened


# ---------------------------------------------------------------------------
# device scheduler


def test_scheduler_runs_jobs_and_relays_errors():
    with DeviceScheduler() as sched:
        assert sched.run(lambda: 42, urgency=0.0) == 42
        with pytest.raises(ValueError, match="boom"):
            sched.run(lambda: (_ for _ in ()).throw(ValueError("boom")),
                      urgency=0.0)
    with pytest.raises(RuntimeError, match="closed"):
        sched.run(lambda: 1, urgency=0.0)


def test_scheduler_orders_by_urgency_then_priority():
    """Queued jobs leave the heap oldest-deadline-first; priority sorts
    above the time key."""
    sched = DeviceScheduler()
    order = []
    gate = threading.Event()
    release = threading.Event()

    def job(tag, wait=False):
        def fn():
            if wait:
                gate.set()
                release.wait(5)
            order.append(tag)
        return fn

    threads = [threading.Thread(
        target=lambda: sched.run(job("hold", wait=True), urgency=0.0))]
    threads[0].start()
    assert gate.wait(5)  # device thread is pinned inside "hold"
    # enqueue out of urgency order while the device is busy
    for tag, urg, pri in (("late", 3.0, 0), ("soon", 1.0, 0),
                          ("mid", 2.0, 0), ("vip", 9.0, 1)):
        t = threading.Thread(
            target=lambda tag=tag, urg=urg, pri=pri: sched.run(
                job(tag), urgency=urg, priority=pri, network=tag))
        t.start()
        threads.append(t)
    deadline = time.perf_counter() + 5
    while sched.stats()["queued"] < 4 and time.perf_counter() < deadline:
        time.sleep(0.005)
    release.set()
    for t in threads:
        t.join(5)
    assert order == ["hold", "vip", "soon", "mid", "late"]
    assert sched.stats()["completed"]["vip"] == 1


def test_scheduler_fairness_fast_network_p95_bounded():
    """Slow + fast network sharing one device: each batcher has at most
    one dispatch in flight, so however deep the slow network's queue
    grows, a fast request waits behind at most one slow dispatch. Fast
    p95 stays under (1 slow + a few fast) service times — never the sum
    of the slow queue."""
    slow_engine = FakeEngine(service_s=0.08)
    fast_engine = FakeEngine(service_s=0.002)
    with DeviceScheduler() as sched:
        with MicroBatcher(slow_engine, max_batch=1, window_ms=0.0,
                          scheduler=sched, name="slow") as slow, \
                MicroBatcher(fast_engine, max_batch=1, window_ms=0.0,
                             scheduler=sched, name="fast") as fast:
            slow_tickets = [slow.submit(_img(i)) for i in range(8)]
            fast_lat = []
            for i in range(10):
                t = fast.submit(_img(i))
                t.result(timeout=30)
                fast_lat.append(t.latency)
            for t in slow_tickets:
                t.result(timeout=30)
    fast_lat.sort()
    p95 = fast_lat[min(len(fast_lat) - 1,
                       round(0.95 * (len(fast_lat) - 1)))]
    # bound: one in-flight slow dispatch (0.08s) + own service + slack.
    # Without per-network in-flight limiting, 8 queued slow dispatches
    # ahead would push this to ~0.64s.
    assert p95 < 0.25, f"fast p95 {p95:.3f}s head-of-line blocked"
    assert sched.stats()["jobs"] >= 18


# ---------------------------------------------------------------------------
# options objects + deprecation shim


def test_legacy_kwargs_warn_and_build_identical_server():
    new = Server(tiny=True, options=ServingOptions(
        max_batch=4, window_ms=3.0, deadline_ms=50.0, max_queue=7,
        breaker_threshold=2, breaker_reset_s=1.5))
    with pytest.warns(DeprecationWarning, match="ServingOptions"):
        old = Server(tiny=True, max_batch=4, window_ms=3.0,
                     deadline_ms=50.0, max_queue=7, breaker_threshold=2,
                     breaker_reset_s=1.5)
    try:
        assert old.options == new.options  # frozen dataclass: full equality
    finally:
        old.close()
        new.close()


def test_legacy_kwargs_conflict_with_options_raises():
    with pytest.raises(ValueError, match="not both"):
        Server(tiny=True, options=ServingOptions(), max_queue=3)


def test_unknown_server_kwarg_is_a_typeerror():
    with pytest.raises(TypeError, match="max_qeue"):
        Server(tiny=True, max_qeue=3)


def test_per_call_dtype_kwarg_warns_and_matches_options(tiny_server):
    import jax

    img = jax.random.normal(jax.random.key(3), (32, 32, 3))
    via_options = tiny_server.run(
        "resnet18", img, options=RequestOptions(dtype="bfloat16"))
    with pytest.warns(DeprecationWarning, match="RequestOptions"):
        via_kwarg = tiny_server.run("resnet18", img, dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(via_options),
                                  np.asarray(via_kwarg))


def test_conflicting_dtypes_raise():
    opts = RequestOptions(dtype="bfloat16")
    with pytest.raises(ValueError, match="conflicting"):
        opts.merged_dtype("float16")
    assert opts.merged_dtype("bfloat16") is opts
    assert opts.merged_dtype(None) is opts


@pytest.fixture(scope="module")
def tiny_server():
    with Server(tiny=True, options=ServingOptions(
            max_batch=4, window_ms=2.0)) as server:
        yield server


# ---------------------------------------------------------------------------
# Ticket


def test_submit_returns_ticket_with_latency_stamps(tiny_server):
    import jax

    img = jax.random.normal(jax.random.key(4), (32, 32, 3))
    ticket = tiny_server.submit("resnet18", img)
    assert isinstance(ticket, Ticket)
    out = ticket.result(timeout=120)
    assert ticket.done()
    assert out.ndim == 1  # (classes,) logits
    assert ticket.latency is not None and ticket.latency > 0
    assert ticket.done_at is not None and ticket.done_at > ticket.arrival
    # run() is submit().result() — same numerics, same handle semantics
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(tiny_server.run("resnet18", img)))


def test_ticket_result_timeout_cancels():
    """The cancel-on-timeout contract moved from Server.run onto
    Ticket.result: a timed-out wait marks the request so the batcher
    sheds it at dequeue."""
    engine = FakeEngine(service_s=0.2)
    with MicroBatcher(engine, max_batch=1, window_ms=0.0) as b:
        hold = b.submit(_img(0))        # occupies the loop 0.2s
        queued = b.submit(_img(1))      # waits behind it
        with pytest.raises(Exception) as ei:
            queued.result(timeout=0.01)
        assert "Timeout" in type(ei.value).__name__
        hold.result(timeout=10)
    assert b.stats()["shed"]["cancelled"] == 1
    assert engine.batches == [1]  # the cancelled request never dispatched


def test_ticket_done_callback_fires():
    engine = FakeEngine()
    seen = []
    with MicroBatcher(engine, max_batch=1, window_ms=0.0) as b:
        t = b.submit(_img(2))
        t.add_done_callback(lambda ticket: seen.append(ticket.id))
        t.result(timeout=10)
    assert seen == [t.id]


# ---------------------------------------------------------------------------
# public surface: examples/docs must not import serving internals


def test_examples_and_docs_use_public_import_surface():
    """Anything under examples/ or docs/ that imports the serving
    subsystem must go through ``repro.serving`` — the internals
    (``repro.serving.request``, ``.resilience``, ...) are free to move."""
    import re
    from pathlib import Path

    root = Path(__file__).resolve().parent.parent
    private = re.compile(
        r"(?:from|import)\s+repro\.serving\.(\w+)")
    offenders = []
    for path in [*(root / "examples").rglob("*.py"),
                 *(root / "docs").rglob("*.md"),
                 root / "README.md"]:
        for i, line in enumerate(path.read_text().splitlines(), 1):
            m = private.search(line)
            if m:
                offenders.append(f"{path.relative_to(root)}:{i} "
                                 f"imports repro.serving.{m.group(1)}")
    assert not offenders, "\n".join(offenders)


def test_public_surface_exports_the_front_door():
    import repro.serving as serving

    for name in ("Server", "ServingOptions", "RequestOptions", "Ticket",
                 "AsyncClient", "ServerEndpoint", "DeviceScheduler",
                 "Rejected", "Overloaded", "DeadlineExceeded",
                 "CircuitOpen", "ProtocolError", "BadRequest",
                 "RemoteError"):
        assert hasattr(serving, name), f"repro.serving.{name} missing"
        assert name in serving.__all__


# ---------------------------------------------------------------------------
# admission + close semantics survive the rework


def test_bounded_queue_sheds_at_admission_mid_flight():
    engine = FakeEngine(service_s=0.2)
    with MicroBatcher(engine, max_batch=1, window_ms=0.0,
                      max_queue=2) as b:
        first = b.submit(_img(0))
        time.sleep(0.05)  # first is mid-dispatch; queue empty again
        ok = [b.submit(_img(1)), b.submit(_img(2))]
        with pytest.raises(Overloaded, match="queue full"):
            b.submit(_img(3))
        for t in [first, *ok]:
            t.result(timeout=10)
    assert b.stats()["shed"]["overload"] == 1


def test_priority_and_deadline_ride_to_the_request():
    engine = FakeEngine()
    with MicroBatcher(engine, max_batch=1, window_ms=0.0) as b:
        req = b.submit_request(_img(0), deadline_ms=5000.0, priority=3)
        assert req.priority == 3
        assert req.deadline is not None
        assert req.urgency == req.deadline
        Ticket(req).result(timeout=10)
        no_dl = b.submit_request(_img(1))
        assert no_dl.deadline is None
        assert no_dl.urgency == no_dl.arrival
        Ticket(no_dl).result(timeout=10)
