"""Precision as a first-class axis: shared dtype rules, kernel parity in
reduced precision, dtype-carrying plans, the quant epilogue fold, and the
serving precision knob.

The parity sweep is the contract docs/algorithms.md documents: every
registered algorithm, run on bf16/fp16 inputs, must match the fp32 lax
ground truth within ``repro.core.dtypes.tolerance(dtype)`` — kernels
accumulate in fp32 and cast once on the output write, so the error budget
tracks the input mantissa, not the reduction depth.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvSpec, build_plan, cost_model_select
from repro.core.dtypes import (
    ACC_BYTES, KERNEL_DTYPES, canonical, element_size, tolerance,
    with_precision)
from repro.kernels import ops, ref
from repro.quant import dequantize, quantize_per_channel

KEY = jax.random.key(42)


# ----------------------------------------------------------------------
# the shared dtype rules (the three hand-rolled copies they replace)


def test_element_size_table():
    assert element_size("float32") == 4
    assert element_size("bfloat16") == 2
    assert element_size("float16") == 2
    assert element_size("int8") == 1  # the seed mis-sized this as 4
    assert element_size(jnp.bfloat16) == 2  # jnp types canonicalize
    assert element_size(jnp.dtype("float16")) == 2
    assert ACC_BYTES == element_size("float32")  # fp32 accumulator rule


def test_element_size_rejects_unknown():
    with pytest.raises(ValueError, match="unknown dtype"):
        element_size("float8_e4m3")


def test_canonical_forms_agree():
    for name in KERNEL_DTYPES:
        assert canonical(name) == name
        assert canonical(jnp.dtype(name)) == name
        assert canonical(getattr(jnp, name)) == name


def test_convspec_element_size_and_bytes_scale_with_dtype():
    sp32 = ConvSpec(h=14, w=14, c=32, k=64)
    sp16 = dataclasses.replace(sp32, dtype="bfloat16")
    assert sp32.element_size == 4 and sp16.element_size == 2
    assert sp16.bytes_min * 2 == sp32.bytes_min
    assert sp16.epilogue_bytes * 2 == sp32.epilogue_bytes
    assert sp16 != sp32  # dtype is part of the tuning key


def test_with_precision_sets_both_dtypes_and_rejects_int8():
    from repro.configs import get

    cfg = with_precision(get("resnet18"), "bfloat16")
    assert (cfg.dtype, cfg.param_dtype) == ("bfloat16", "bfloat16")
    assert with_precision(cfg, "bfloat16") is cfg  # already there: no-op
    with pytest.raises(ValueError, match="int8 is a storage format"):
        with_precision(cfg, "int8")


def test_cost_model_charges_dtype_correct_bytes():
    """Halving the element width must halve the picked candidate's byte
    traffic — the mechanism that lets reduced precision flip a site's
    winning algorithm where the roofline crossover moves."""
    sp32 = ConvSpec(h=28, w=28, c=64, k=128)
    for dt in ("bfloat16", "float16"):
        ch16 = cost_model_select(dataclasses.replace(sp32, dtype=dt))
        ch32 = cost_model_select(sp32)
        assert ch16.est_bytes <= -(-ch32.est_bytes // 2) + 1
        assert ch16.est_time <= ch32.est_time


# ----------------------------------------------------------------------
# kernel parity: every registered algorithm x {fp32, bf16, fp16} x stride


def _sweep_cases():
    for algo in sorted(ops.ALGORITHMS):
        strides = (1, 2) if algo in ("ilpm", "direct", "depthwise",
                                     "pointwise") else (1,)
        for stride in strides:
            yield algo, stride


def _spec_for(algo, stride):
    if algo == "depthwise":
        return ConvSpec(h=8, w=8, c=8, k=8, stride=stride, groups=8)
    if algo == "pointwise":
        return ConvSpec(h=8, w=8, c=8, k=16, r=1, s=1, stride=stride)
    return ConvSpec(h=8, w=8, c=8, k=16, stride=stride)


@pytest.mark.parametrize("dtype", KERNEL_DTYPES)
@pytest.mark.parametrize("algo,stride", list(_sweep_cases()),
                         ids=lambda v: str(v))
def test_kernel_parity_across_dtypes(algo, stride, dtype):
    """Pallas kernel output on dtype inputs vs the fp32 lax ground truth
    of the *same values*: within the documented tolerance(dtype)."""
    spec = _spec_for(algo, stride)
    hp = (spec.out_h - 1) * stride + spec.r
    wp = (spec.out_w - 1) * stride + spec.s
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEY, (1, hp, wp, spec.c), dt)
    w = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (spec.r, spec.s, spec.c_per_group, spec.k), dt)
    gt = ref.conv2d_reference(x.astype(jnp.float32),
                              w.astype(jnp.float32), stride=stride,
                              padding="VALID", groups=spec.groups)
    y = ops.dispatch(algo, x, w, impl="pallas", stride=stride)
    assert y.dtype == dt  # cast-on-write: output carries the input dtype
    rel = float(jnp.abs(y.astype(jnp.float32) - gt).max()
                / (jnp.abs(gt).max() + 1e-12))
    assert rel < tolerance(dtype), (algo, stride, dtype, rel)


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_fused_epilogue_in_reduced_precision(dtype):
    """scale/bias/act fuse in fp32 inside the kernel even when the conv
    runs in reduced precision — parity against the fp32 unfused math."""
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEY, (1, 10, 10, 8), dt)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 3, 8, 16), dt)
    scale = jax.random.normal(jax.random.fold_in(KEY, 3), (16,))
    bias = jax.random.normal(jax.random.fold_in(KEY, 4), (16,))
    gt = ref.conv2d_reference(x.astype(jnp.float32), w.astype(jnp.float32),
                              padding="VALID")
    gt = jax.nn.relu(gt * scale + bias)
    y = ops.dispatch("ilpm", x, w, impl="pallas", scale=scale, bias=bias,
                     act="relu")
    rel = float(jnp.abs(y.astype(jnp.float32) - gt).max()
                / (jnp.abs(gt).max() + 1e-12))
    assert rel < tolerance(dtype), rel


# ----------------------------------------------------------------------
# plans carry dtype


def test_plan_json_round_trip_preserves_dtype(tmp_path):
    specs = [("l0", ConvSpec(h=8, w=8, c=8, k=16, dtype="bfloat16")),
             ("l1", ConvSpec(h=8, w=8, c=16, k=16, r=1, s=1,
                             dtype="bfloat16"))]
    plan = build_plan(specs, epilogue=True)
    path = tmp_path / "plan.json"
    plan.save(path)
    from repro.core import TuningPlan

    loaded = TuningPlan.load(path)
    assert loaded.specs == plan.specs
    assert {s.dtype for s in loaded.specs.values()} == {"bfloat16"}
    assert loaded.choices == plan.choices


def test_engine_rejects_cross_dtype_plan(tmp_path):
    """A plan tuned in fp32 must not deploy onto a bf16 engine: ConvSpec
    carries dtype, so validation sees mismatched specs."""
    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine

    cfg32 = tiny_variant(get("resnet18"))
    e32 = InferenceEngine(cfg32)
    path = tmp_path / "plan32.json"
    e32.save_plan(path)
    with pytest.raises(ValueError, match="dtype"):
        InferenceEngine(with_precision(cfg32, "bfloat16"), plan=str(path))


@pytest.mark.parametrize("network", ["resnet18", "mobilenet_v2"])
@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_full_size_reduced_precision_plans_have_no_xla_sites(network,
                                                            dtype):
    """The acceptance bar: tuned full-size ResNet-18 / MobileNetV2 plans
    in reduced precision keep 100% of the backbone on kernel families."""
    from repro.configs import get
    from repro.models.registry import cnn_module

    cfg = with_precision(get(network), dtype)
    plan = build_plan(cnn_module(cfg).conv_specs(cfg), epilogue=True)
    algos = plan.algorithms()
    assert algos, network
    xla = [n for n, a in algos.items() if a == "xla"]
    assert xla == [], xla
    assert {s.dtype for s in plan.specs.values()} == {dtype}


# ----------------------------------------------------------------------
# int8: quantize core + epilogue folding


def test_compression_reexports_shared_quant_core():
    from repro.optim import compression
    from repro import quant

    assert compression.quantize is quant.quantize
    assert compression.dequantize is quant.dequantize


def test_per_channel_quantize_bounds_rounding_error():
    w = jax.random.normal(KEY, (3, 3, 8, 16))
    codes, scales = quantize_per_channel(w)
    assert codes.dtype == jnp.int8 and scales.shape == (16,)
    err = jnp.abs(w - dequantize(codes, scales))
    # symmetric rounding: at most half a step per channel
    assert bool((err <= scales / 2 + 1e-7).all())


def test_int8_epilogue_folding_identity():
    """conv(x, codes)·s_k == conv(x, codes·s_k): the linearity that lets
    the per-channel dequant multiply ride the existing fused epilogue."""
    x = jax.random.normal(KEY, (1, 10, 10, 8))
    w = jax.random.normal(jax.random.fold_in(KEY, 5), (3, 3, 8, 16))
    codes, scales = quantize_per_channel(w)
    folded = ops.dispatch("ilpm", x, codes.astype(jnp.float32),
                          impl="pallas", scale=scales,
                          bias=jnp.zeros((16,)))
    direct = ref.conv2d_reference(x, dequantize(codes, scales),
                                  padding="VALID")
    np.testing.assert_allclose(np.asarray(folded), np.asarray(direct),
                               rtol=2e-4, atol=2e-4)


def test_quantize_params_folds_scales_and_reports():
    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine
    from repro.quant import quantization_error, quantize_params

    cfg = tiny_variant(get("resnet18"))
    eng = InferenceEngine(cfg)
    qparams, report = quantize_params(eng.params)
    assert report  # conv sites were found
    for name, q in report.items():
        assert q.codes.dtype == jnp.int8
        assert q.storage_bytes < q.codes.size * 4  # beats fp32 storage
    assert max(quantization_error(eng.params, report).values()) < 0.02
    # the quantized tree runs the unchanged forward on the same plan
    qeng = InferenceEngine(cfg, params=qparams, plan=eng.plan)
    img = jax.random.normal(KEY, (32, 32, 3))
    y = np.asarray(eng.run(img), np.float32)
    yq = np.asarray(qeng.run(img), np.float32)
    rel = np.abs(y - yq).max() / (np.abs(y).max() + 1e-12)
    assert rel < 0.05, rel  # weight-only int8: small logit perturbation


# ----------------------------------------------------------------------
# serving precision knob


def test_server_precision_knob_routes_to_dtype_variant():
    from repro.serving import Server

    img = jax.random.normal(KEY, (32, 32, 3))
    with Server(tiny=True, window_ms=5.0) as server:
        y16 = server.run("resnet18", img, dtype="bfloat16")
        assert y16.dtype == jnp.bfloat16
        y32 = server.run("resnet18", img)
        assert y32.dtype == jnp.float32
        keys = server.stats()["cache"]["keys"]
        assert any("bfloat16" in k for k in keys)
        assert any("float32" in k for k in keys)
        # two engines (one per precision), each tuned under its own plan
        assert server.stats()["cache"]["misses"] == 2


def test_stream_session_reports_dtype():
    from repro.serving import Server

    with Server(tiny=True) as server:
        s = server.open_stream("resnet18", fps=30.0, sim_compute_s=0.001,
                               dtype="bfloat16")
        assert s.stats()["dtype"] == "bfloat16"
        s.close()
