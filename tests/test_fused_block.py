"""Fused-block megakernels: adversarial parity vs the composed per-layer
chain, the cost model's saved-round-trip charging rule, block-carrying
plan round-trips, and the full-network fused-vs-per-layer acceptance bar.

Two parity tiers, on purpose:

  * vs the fp32 *reference* chain (``ref.fused_inverted_residual``) the
    fused kernel holds the documented ``tolerance(dtype)`` across the
    stride x expansion x dtype x residual matrix — the same contract every
    per-conv kernel signs in test_precision.py;
  * vs the composed per-layer *Pallas* chain at fp32 the fused kernel is
    BITWISE equal (it mirrors those kernels' accumulation stage for
    stage), which is what makes the fused-plan vs per-layer-plan
    full-network logits comparison exact rather than approximate.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import (ConvSpec, FusedBlockSpec, InferenceEngine,
                        TuningPlan, build_plan, select_block)
from repro.core.autotune import block_baseline_time, block_constituents
from repro.core.dtypes import KERNEL_DTYPES, tolerance
from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _ir_weights(cin, mid, cout, dtype, r=3):
    """A full inverted-residual weight set; the expansion stage (w1/s1/b1)
    is included only when mid != cin (t > 1)."""
    dt = jnp.dtype(dtype)
    k = jax.random.fold_in(KEY, cin * mid * cout)
    ks = jax.random.split(k, 9)
    w = {"wdw": jax.random.normal(ks[0], (r, r, 1, mid), dt),
         "sdw": jax.random.normal(ks[1], (mid,)) * 0.5 + 1.0,
         "bdw": jax.random.normal(ks[2], (mid,)) * 0.1,
         "w2": jax.random.normal(ks[3], (1, 1, mid, cout), dt) * 0.2,
         "s2": jax.random.normal(ks[4], (cout,)) * 0.5 + 1.0,
         "b2": jax.random.normal(ks[5], (cout,)) * 0.1}
    if mid != cin:
        w.update({"w1": jax.random.normal(ks[6], (1, 1, cin, mid), dt) * 0.3,
                  "s1": jax.random.normal(ks[7], (mid,)) * 0.5 + 1.0,
                  "b1": jax.random.normal(ks[8], (mid,)) * 0.1})
    return w


# residual demands stride == 1 and cin == cout; everything else sweeps
_IR_CASES = [(stride, t, residual)
             for stride in (1, 2) for t in (1, 6)
             for residual in (False, True)
             if not (residual and stride == 2)]


@pytest.mark.parametrize("dtype", KERNEL_DTYPES)
@pytest.mark.parametrize("stride,t,residual", _IR_CASES,
                         ids=lambda v: str(v))
def test_fused_inverted_residual_parity_vs_reference(stride, t, residual,
                                                     dtype):
    """Fused megakernel on dtype inputs vs the fp32 composed reference of
    the same values: within the documented tolerance(dtype)."""
    cin = 8
    cout = cin if residual else 16
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEY, (1, 8, 8, cin), dt)
    w = _ir_weights(cin, cin * t, cout, dtype)
    gt = ref.fused_inverted_residual(
        x.astype(jnp.float32),
        {k: v.astype(jnp.float32) for k, v in w.items()},
        stride=stride, residual=residual)
    y = ops.fused_inverted_residual(x, w, impl="pallas", stride=stride,
                                    residual=residual)
    assert y.dtype == dt  # cast-on-write: output carries the input dtype
    assert y.shape == gt.shape
    rel = float(jnp.abs(y.astype(jnp.float32) - gt).max()
                / (jnp.abs(gt).max() + 1e-12))
    assert rel < tolerance(dtype), (stride, t, residual, dtype, rel)


@pytest.mark.parametrize("stride,t,residual", _IR_CASES,
                         ids=lambda v: str(v))
def test_fused_inverted_residual_bitwise_vs_per_layer_pallas(stride, t,
                                                             residual):
    """At fp32 the fused kernel is bitwise equal to the composed per-layer
    Pallas chain (expand -> pad -> depthwise -> project [-> +x]) — it
    mirrors those kernels' accumulation and cast points exactly. This is
    the kernel-level fact underneath the full-network logits equality."""
    cin = 8
    cout = cin if residual else 16
    x = jax.random.normal(KEY, (1, 8, 8, cin))
    w = _ir_weights(cin, cin * t, cout, "float32")
    e = x
    if t > 1:
        e = ops.dispatch("pointwise", x, w["w1"], impl="pallas",
                         scale=w["s1"], bias=w["b1"], act="relu6")
    ep = ref.pad_same(e, 3, 3, stride)
    d = ops.dispatch("depthwise", ep, w["wdw"], impl="pallas",
                     stride=stride, scale=w["sdw"], bias=w["bdw"],
                     act="relu6")
    y = ops.dispatch("pointwise", d, w["w2"], impl="pallas",
                     scale=w["s2"], bias=w["b2"])
    if residual:
        y = y + x
    yf = ops.fused_inverted_residual(x, w, impl="pallas", stride=stride,
                                     residual=residual)
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(y))


def test_fused_inverted_residual_multi_slab_matches_single_slab():
    """Slicing the expanded width into slabs (the tuned block_m) only
    reorders the projection's fp32 accumulation; a non-dividing block_m
    falls back to the single-slab variant rather than double-counting a
    ragged slab."""
    x = jax.random.normal(KEY, (1, 8, 8, 8))
    w = _ir_weights(8, 48, 16, "float32")
    y1 = ops.fused_inverted_residual(x, w, impl="pallas", block_m=48)
    y2 = ops.fused_inverted_residual(x, w, impl="pallas", block_m=24)
    y3 = ops.fused_inverted_residual(x, w, impl="pallas", block_m=20)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(y3), np.asarray(y1))


@pytest.mark.parametrize("rs,block_k", [(3, 128), (1, 128), (3, 16)],
                         ids=("3x3", "1x1", "ragged-k"))
def test_fused_residual_conv_bitwise_vs_per_layer_pallas(rs, block_k):
    """conv + shortcut add + outer ReLU in one write == the per-layer
    ilpm conv followed by the separate add pass, bitwise at fp32 —
    including a block_k that does not divide K."""
    C, K = 16, 24
    x = jax.random.normal(KEY, (1, 8, 8, C))
    ks = jax.random.split(jax.random.fold_in(KEY, rs), 4)
    w = {"w": jax.random.normal(ks[0], (rs, rs, C, K)) * 0.2,
         "scale": jax.random.normal(ks[1], (K,)) * 0.5 + 1.0,
         "bias": jax.random.normal(ks[2], (K,)) * 0.1}
    res = jax.random.normal(ks[3], (1, 8, 8, K))
    xp = ref.pad_same(x, rs, rs)
    y = ops.dispatch("ilpm", xp, w["w"], impl="pallas",
                     scale=w["scale"], bias=w["bias"])
    y = ref.apply_act(y + res, "relu")
    yf = ops.fused_residual_conv(xp, w, impl="pallas", res=res,
                                 block_k=block_k)
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(y))


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_fused_residual_conv_reduced_precision_parity(dtype):
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEY, (1, 8, 8, 16), dt)
    ks = jax.random.split(KEY, 2)
    w = {"w": jax.random.normal(ks[0], (3, 3, 16, 16), dt) * 0.2}
    res = jax.random.normal(ks[1], (1, 8, 8, 16), dt)
    xp = ref.pad_same(x, 3, 3)
    gt = ref.fused_residual_conv(xp.astype(jnp.float32),
                                 {"w": w["w"].astype(jnp.float32)},
                                 res=res.astype(jnp.float32))
    y = ops.fused_residual_conv(xp, w, impl="pallas", res=res)
    assert y.dtype == dt
    rel = float(jnp.abs(y.astype(jnp.float32) - gt).max()
                / (jnp.abs(gt).max() + 1e-12))
    assert rel < tolerance(dtype), rel


# ----------------------------------------------------------------------
# the cost model's charging rule


def _ir_bspec(dtype="float32"):
    return FusedBlockSpec("inverted_residual", h=16, w=16, cin=24, mid=144,
                          cout=32, stride=2, dtype=dtype)


def _block_specs_under_test():
    return [_ir_bspec(),
            FusedBlockSpec("inverted_residual", h=8, w=8, cin=32, mid=192,
                           cout=32, residual=True),
            FusedBlockSpec("inverted_residual", h=16, w=16, cin=32, mid=32,
                           cout=32, residual=True),  # t == 1
            FusedBlockSpec("residual_conv", h=8, w=8, cin=64, mid=64,
                           cout=64, residual=True),
            FusedBlockSpec("residual_conv", h=8, w=8, cin=64, mid=64,
                           cout=256, r=1, s=1, residual=True)]


@pytest.mark.parametrize("bspec", _block_specs_under_test(),
                         ids=lambda b: f"{b.kind}-{b.mid}-{b.cout}")
def test_fused_bytes_below_per_layer_sum_by_exactly_saved_bytes(bspec):
    """The charging rule, to the byte: the fused candidate's HBM estimate
    is the per-layer constituent sum minus the round-trips that now stay
    in VMEM (plus, for residual_conv only, one read of the shortcut
    operand — a different tensor, unlike the inverted residual's identity,
    which is the already-resident input)."""
    ch = select_block(bspec)
    assert ch is not None  # the tuner fuses every one of these sites
    per_layer = sum(c.est_bytes for c in block_constituents(bspec))
    shortcut_read = (bspec.element_size * bspec.batch * bspec.out_h
                     * bspec.out_w * bspec.cout
                     if bspec.kind == "residual_conv" else 0)
    assert ch.est_bytes == per_layer - bspec.saved_bytes + shortcut_read
    assert ch.est_bytes < per_layer  # strictly below the constituent sum
    assert ch.est_time < block_baseline_time(bspec)


def test_saved_bytes_scale_with_dtype():
    """Halving the element width halves the saved round-trip — dtype is
    part of the block tuning key for the same reason it is for ConvSpec."""
    b32 = _ir_bspec()
    b16 = dataclasses.replace(b32, dtype="bfloat16")
    assert b32.saved_bytes > 0
    assert b16.saved_bytes * 2 == b32.saved_bytes
    ch32, ch16 = select_block(b32), select_block(b16)
    assert ch16 is not None and ch16.est_bytes < ch32.est_bytes


def test_select_block_prefers_single_slab_and_dividing_block_m():
    """Every slab width moves the same bytes, so the single-slab variant
    (bitwise-identical reduction order to the per-layer chain) wins ties;
    any tuned block_m divides mid exactly."""
    ch = select_block(_ir_bspec())
    assert ch.algorithm == "fused_inverted_residual"
    bm = dict(ch.params)["block_m"]
    assert _ir_bspec().mid % bm == 0


def test_build_plan_records_block_winners_and_keeps_conv_entries():
    """Block fusion is additive: the plan still carries a per-conv entry
    for every constituent site, so it deploys on engines without block
    support; the block winner rides in its own `<name>.block` section."""
    bspec = _ir_bspec()
    conv_specs = [(f"blk.{n}", cs) for n, cs in bspec.conv_specs()]
    plan = build_plan(conv_specs, block_specs=[("blk.block", bspec)])
    assert set(plan.choices) == {n for n, _ in conv_specs}
    assert set(plan.block_choices) == {"blk.block"}
    assert plan.block_choices["blk.block"].algorithm \
        == "fused_inverted_residual"


# ----------------------------------------------------------------------
# plans carry blocks: round-trip, deploy, cross-dtype rejection


def test_mixed_plan_json_round_trip(tmp_path):
    conv_specs = [("a", ConvSpec(h=8, w=8, c=16, k=16)),
                  ("b", ConvSpec(h=8, w=8, c=16, k=32, r=1, s=1))]
    blocks = [("ir.block", _ir_bspec()),
              ("rc.block", FusedBlockSpec("residual_conv", h=8, w=8,
                                          cin=64, mid=64, cout=64,
                                          residual=True))]
    plan = build_plan(conv_specs, block_specs=blocks)
    assert len(plan.block_choices) == 2
    back = TuningPlan.from_json(plan.to_json())
    assert back.choices == plan.choices
    assert back.block_specs == plan.block_specs
    assert back.block_choices == plan.block_choices
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = TuningPlan.load(path)
    assert loaded.block_specs == plan.block_specs
    assert loaded.block_choices == plan.block_choices


def test_block_plan_survives_save_load_deploy(tmp_path):
    """Tune-once / deploy-many holds for block-carrying plans: the loaded
    plan drives the same fused dispatch and the same logits."""
    cfg = tiny_variant(get("mobilenet_v2"))
    eng = InferenceEngine(cfg)
    assert eng.plan.block_choices  # acceptance: >= 1 fused block
    path = tmp_path / "plan.json"
    eng.save_plan(path)
    img = jax.random.normal(KEY, (32, 32, 3))
    eng2 = InferenceEngine(cfg, params=eng.params, plan=str(path))
    assert eng2.plan.block_choices == eng.plan.block_choices
    np.testing.assert_array_equal(np.asarray(eng2.run(img)),
                                  np.asarray(eng.run(img)))


def test_engine_rejects_cross_dtype_block_plan():
    """Per-conv entries matching is not enough: a block entry tuned at a
    different dtype must fail deploy validation (its saved-bytes
    accounting — and its kernel's cast points — are dtype-specific)."""
    cfg = tiny_variant(get("mobilenet_v2"))
    eng = InferenceEngine(cfg)
    bad = copy.deepcopy(eng.plan)
    bad.block_specs = {n: dataclasses.replace(s, dtype="bfloat16")
                       for n, s in bad.block_specs.items()}
    with pytest.raises(ValueError, match="mismatched block specs"):
        InferenceEngine(cfg, params=eng.params, plan=bad)


# ----------------------------------------------------------------------
# the acceptance bar: whole-network logits, fused plan vs per-layer plan


def _strip_blocks(plan):
    p = copy.deepcopy(plan)
    p.block_choices.clear()
    p.block_specs.clear()
    return p


@pytest.mark.parametrize("network", ["mobilenet_v2", "resnet18"])
def test_full_network_fused_vs_per_layer_logits_bitwise(network):
    """At fp32 the fused-plan forward and the per-layer-plan forward
    produce bitwise-identical logits: fusion changes where intermediates
    live (VMEM vs HBM), never a single ULP of the math."""
    cfg = tiny_variant(get(network))
    eng = InferenceEngine(cfg)
    assert eng.plan.block_choices, network
    img = jax.random.normal(KEY, (32, 32, 3))
    fused = np.asarray(eng.run(img))
    per_layer_eng = InferenceEngine(cfg, params=eng.params,
                                    plan=_strip_blocks(eng.plan))
    np.testing.assert_array_equal(np.asarray(per_layer_eng.run(img)), fused)
    assert not np.isnan(fused).any()
