"""Serving subsystem tests: engine cache, micro-batcher, end-to-end server.

The correctness bar is the issue's: concurrent single-image requests
through the micro-batching server must produce outputs *bitwise-equal* to
sequential tuned-engine runs — batching may change scheduling, never
numerics — and the LRU engine cache must return the identical engine
(jitted exactly once) for a repeated (network, input_size, device, dtype).
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine
from repro.core import engine as engine_mod
from repro.serving import EngineCache, MicroBatcher, Server, bucket, engine_key

KEY = jax.random.key(7)
RESNET = tiny_variant(get("resnet18"))
MOBILENET = tiny_variant(get("mobilenet_v2"))


def _images(n, size=32):
    return [jax.random.normal(jax.random.fold_in(KEY, i), (size, size, 3))
            for i in range(n)]


# ----------------------------------------------------------------------
# engine cache


def test_cache_hit_returns_identical_engine_jit_once(monkeypatch):
    """Same (network, input_size, device, dtype) -> the same engine object,
    with jax.jit invoked only for the single build (spy-counted)."""
    real_jit = jax.jit
    jit_calls = []

    def counting_jit(*args, **kwargs):
        jit_calls.append(args)
        return real_jit(*args, **kwargs)

    monkeypatch.setattr(engine_mod.jax, "jit", counting_jit)
    cache = EngineCache(capacity=2)
    e1 = cache.get(RESNET)
    n_build = len(jit_calls)
    assert n_build >= 1  # the engine's forward(s) were jitted
    e2 = cache.get(RESNET)
    assert e2 is e1  # identical object: same jit, same params, same plan
    assert len(jit_calls) == n_build  # hit jits nothing
    assert (cache.hits, cache.misses) == (1, 1)


def test_cache_distinct_keys_miss():
    cache = EngineCache(capacity=4)
    e1 = cache.get(RESNET)
    e2 = cache.get(MOBILENET)
    assert e1 is not e2
    assert cache.misses == 2 and cache.hits == 0
    assert engine_key(RESNET) != engine_key(MOBILENET)
    assert len(cache) == 2


def test_cache_lru_evicts_beyond_capacity():
    cache = EngineCache(capacity=1)
    e1 = cache.get(RESNET)
    cache.get(MOBILENET)  # evicts the resnet engine
    assert cache.evictions == 1
    assert MOBILENET in cache and RESNET not in cache
    e3 = cache.get(RESNET)  # rebuilt: a fresh object...
    assert e3 is not e1
    # ...but through the plan-reuse hook: same geometry -> the cached
    # TuningPlan is handed to the new engine instead of re-tuning
    assert e3.plan is e1.plan


def test_cache_plan_reuse_across_dtype_variants():
    """(network, input_size, compute_dtype) keys the plan. A variant
    differing only in param *storage* dtype shares the tuned plan (it was
    tuned for the compute dtype, which is what the kernels stream); a
    variant with a different *compute* dtype must NOT — its ConvSpecs
    carry the dtype and its byte traffic differs. The seed keyed plans by
    geometry alone, silently deploying fp32 choices onto bf16 engines."""
    from repro.core import with_precision

    cache = EngineCache(capacity=4)
    e32 = cache.get(RESNET)
    e_store16 = cache.get(RESNET.replace(param_dtype="bfloat16"))
    assert e_store16 is not e32  # distinct engine cache entries
    assert e_store16.plan is e32.plan  # storage-only variant: no re-tune

    e_bf16 = cache.get(with_precision(RESNET, "bfloat16"))
    assert e_bf16 is not e32
    assert e_bf16.plan is not e32.plan  # compute dtype gets its own plan
    assert {s.dtype for s in e_bf16.plan.specs.values()} == {"bfloat16"}
    assert {s.dtype for s in e32.plan.specs.values()} == {"float32"}
    assert cache.misses == 3


# ----------------------------------------------------------------------
# micro-batcher


def test_bucket_powers_of_two():
    assert [bucket(n, 8) for n in range(1, 9)] == [1, 2, 4, 4, 8, 8, 8, 8]
    assert bucket(3, 3) == 3  # cap wins over the power of two


def test_batcher_matches_sequential_bitwise_with_ragged_tail():
    """6 requests through a max_batch=4 batcher -> one full batch + one
    ragged batch of 2, all bitwise-equal to sequential engine.run."""
    eng = InferenceEngine(RESNET)
    imgs = _images(6)
    seq = [np.asarray(eng.run(im)) for im in imgs]
    with MicroBatcher(eng, max_batch=4, window_ms=250.0) as b:
        futs = [b.submit(im) for im in imgs]
        outs = [np.asarray(f.result(timeout=600)) for f in futs]
    for s, o in zip(seq, outs):
        assert np.array_equal(s, o)  # bitwise, not allclose
    sizes = sorted(d["batch"] for d in b.dispatches)
    assert sum(sizes) == 6
    assert sizes[-1] > 1  # traffic actually coalesced
    if sizes == [2, 4]:  # the expected split: full batch + ragged tail
        ragged = next(d for d in b.dispatches if d["batch"] == 2)
        assert ragged["padded"] == 2  # bucket(2) — padded, not max_batch


def test_batcher_single_request_takes_fast_path(monkeypatch):
    """A lone request must go through engine.run (the paper's single-image
    path), never the batched dispatch."""
    eng = InferenceEngine(RESNET)
    calls = []
    real_run, real_run_batch = eng.run, eng.run_batch
    monkeypatch.setattr(eng, "run",
                        lambda im: calls.append("run") or real_run(im))
    monkeypatch.setattr(eng, "run_batch",
                        lambda ims: calls.append("batch") or real_run_batch(ims))
    with MicroBatcher(eng, max_batch=4, window_ms=1.0) as b:
        out = b.submit(_images(1)[0]).result(timeout=600)
    assert calls == ["run"]
    assert out.shape == (RESNET.vocab_size,)


def test_batcher_padding_bounds_traces():
    """Ragged batch sizes pad to power-of-two buckets, so distinct traced
    batch shapes stay O(log max_batch) regardless of traffic pattern."""
    eng = InferenceEngine(RESNET)
    with MicroBatcher(eng, max_batch=4, window_ms=250.0) as b:
        for n in (3, 2, 3):  # three ragged bursts
            futs = [b.submit(im) for im in _images(n)]
            for f in futs:
                f.result(timeout=600)
    padded = {d["padded"] for d in b.dispatches if d["batch"] > 1}
    assert padded <= {2, 4}
    traces = eng.trace_count()
    if traces is not None:  # jax exposes the jit cache size
        assert traces <= 2  # one per bucket, not one per batch size


def test_batcher_dispatch_error_resolves_futures():
    """A failing dispatch must surface on the futures, not kill the loop."""
    eng = InferenceEngine(RESNET)
    with MicroBatcher(eng, max_batch=2, window_ms=1.0) as b:
        bad = b.submit(jax.numpy.zeros((5, 5, 5, 5)))  # bogus image shape
        with pytest.raises(Exception):
            bad.result(timeout=600)
        ok = b.submit(_images(1)[0])  # loop survives and keeps serving
        assert ok.result(timeout=600).shape == (RESNET.vocab_size,)


# ----------------------------------------------------------------------
# server end-to-end


def test_server_concurrent_two_networks_bitwise():
    """N concurrent single-image submissions per network, one shared-cache
    server process, outputs bitwise-equal to sequential engine runs."""
    imgs = _images(5)
    truth = {}
    engines = {"resnet18": InferenceEngine(RESNET),
               "mobilenet_v2": InferenceEngine(MOBILENET)}
    for net, eng in engines.items():
        truth[net] = [np.asarray(eng.run(im)) for im in imgs]

    with Server(tiny=True, max_batch=4, window_ms=100.0) as server:
        for net in engines:
            server.warm(net)
        futures = {net: [None] * len(imgs) for net in engines}

        def client(net):
            for i, im in enumerate(imgs):
                futures[net][i] = server.submit(net, im)

        threads = [threading.Thread(target=client, args=(net,))
                   for net in engines]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = {net: [np.asarray(f.result(timeout=600)) for f in fs]
                for net, fs in futures.items()}
        stats = server.stats()

    for net in engines:
        for s, o in zip(truth[net], outs[net]):
            assert np.array_equal(s, o)
    assert stats["cache"]["misses"] == 2  # one engine build per network
    assert len(stats["networks"]) == 2
    for b in stats["networks"].values():
        assert b["requests"] == len(imgs)


def test_server_submit_after_close_raises():
    server = Server(tiny=True)
    server.close()
    with pytest.raises(RuntimeError):
        server.submit("resnet18", _images(1)[0])
