"""Streaming subsystem tests: per-stream engine leases, StreamSession
deadline accounting under the simulated clock, skip-to-latest frame
drops, the multi-stream scheduler sharing one cache with classify
traffic, and the batcher telemetry satellites.

The correctness bar mirrors serving's: per-frame outputs must be
*bitwise-equal* to sequential ``engine.run`` calls (streaming changes
scheduling and memory traffic, never numerics), deadline misses must be
zero when compute is faster than the frame period and nonzero — with
skip-to-latest engaging — when it is artificially slowed, and a leased
engine must survive LRU pressure for the lease's lifetime.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.serving import (
    EngineCache,
    FrameDropped,
    MicroBatcher,
    Server,
    StreamScheduler,
    StreamSession,
    engine_key,
)

KEY = jax.random.key(11)
RESNET = tiny_variant(get("resnet18"))
MOBILENET = tiny_variant(get("mobilenet_v2"))


def _images(n, size=32):
    return [jax.random.normal(jax.random.fold_in(KEY, i), (size, size, 3))
            for i in range(n)]


@pytest.fixture(scope="module")
def cache():
    """One shared cache for the session tests (engines build once)."""
    return EngineCache(capacity=4)


# ----------------------------------------------------------------------
# engine leases


def test_lease_pins_entry_against_eviction():
    """A leased engine rides outside the capacity count: LRU pressure
    evicts around it, never through it; release rejoins LRU order as MRU."""
    cache = EngineCache(capacity=1)
    lease = cache.lease(RESNET)
    cache.get(MOBILENET)  # would evict the resnet engine without the pin
    assert RESNET in cache and MOBILENET in cache
    assert cache.evictions == 0
    assert cache.get(RESNET) is lease.engine  # still the identical engine
    bf16 = RESNET.replace(param_dtype="bfloat16")
    cache.get(bf16)  # second *unpinned* entry: evicts mobilenet, not resnet
    assert cache.evictions == 1
    assert MOBILENET not in cache and RESNET in cache and bf16 in cache
    assert cache.stats()["pinned"] == [engine_key(RESNET)]
    lease.release()  # back to normal LRU order, as most-recently-used...
    assert cache.stats()["pinned"] == []
    assert RESNET in cache and bf16 not in cache  # ...so bf16 was oldest
    cache.get(MOBILENET)  # now unpinned resnet is evictable again
    assert RESNET not in cache
    assert lease.released


def test_lease_stacks_and_context_manager():
    cache = EngineCache(capacity=1)
    with cache.lease(RESNET) as l1:
        with cache.lease(RESNET) as l2:
            assert l2.engine is l1.engine
            assert cache.leases == 2
        assert cache.stats()["pinned"] == [engine_key(RESNET)]  # l1 holds
    assert cache.stats()["pinned"] == []


def test_lease_held_classify_for_other_network_progresses(cache):
    """Satellite: a held stream lease never blocks classify submits for
    other networks — builds run under per-key locks, dispatch on the
    batcher's own thread."""
    lease = cache.lease(RESNET)
    try:
        img = _images(1)[0]
        with Server(cache=cache, tiny=True, window_ms=1.0) as server:
            out = server.run("mobilenet_v2", img, timeout=600)
        truth = cache.get(MOBILENET).run(img)
        assert np.array_equal(np.asarray(truth), np.asarray(out))
    finally:
        lease.release()


# ----------------------------------------------------------------------
# StreamSession: simulated clock (deterministic deadline accounting)


def test_stream_sim_fast_compute_zero_misses_bitwise(cache):
    """Compute faster than the frame period -> every frame completes on
    time, outputs bitwise-equal to sequential engine.run calls."""
    eng = cache.get(RESNET)
    imgs = _images(8)
    truth = [np.asarray(eng.run(im)) for im in imgs]
    s = StreamSession(cache.lease(RESNET), fps=30.0, sim_compute_s=0.005,
                      name="fast")
    with s:
        frames = [s.submit_frame(im) for im in imgs]
        s.flush()
        outs = [np.asarray(f.future.result(timeout=600)) for f in frames]
    st = s.stats()
    assert st["frames"] == 8 and st["completed"] == 8
    assert st["dropped"] == 0
    assert st["deadline_misses"] == 0 and st["deadline_miss_rate"] == 0.0
    for t, o in zip(truth, outs):
        assert np.array_equal(t, o)  # bitwise, not allclose
    for k, f in enumerate(frames):  # auto-paced arrivals, exact sim stamps
        assert f.arrival == pytest.approx(k / 30.0)
        assert f.dispatch == f.arrival  # device always idle by arrival
        assert f.done == f.dispatch + 0.005
        assert f.missed is False


def test_stream_sim_slow_compute_drops_and_misses(cache):
    """Compute slower than the frame period -> skip-to-latest engages
    (stale frames dropped, freshest kept) and the miss rate is nonzero;
    frames that do complete are still bitwise-correct."""
    eng = cache.get(RESNET)
    imgs = _images(10)
    truth = [np.asarray(eng.run(im)) for im in imgs]
    s = StreamSession(cache.lease(RESNET), fps=30.0, sim_compute_s=0.08,
                      name="slow")
    frames = [s.submit_frame(im) for im in imgs]
    s.close()  # flushes the pending slot
    st = s.stats()
    assert st["frames"] == 10
    assert st["dropped"] > 0  # skip-to-latest engaged
    assert st["deadline_misses"] > 0 and st["deadline_miss_rate"] > 0
    assert st["completed"] + st["dropped"] == 10
    assert not frames[-1].dropped  # the freshest frame always survives
    completed = [f for f in frames if not f.dropped]
    for f in completed:
        assert f.done > f.deadline  # 80 ms compute vs 33 ms deadline
        assert np.array_equal(truth[f.seq],
                              np.asarray(f.future.result(timeout=600)))
    dropped = next(f for f in frames if f.dropped)
    with pytest.raises(FrameDropped):
        dropped.future.result(timeout=600)


def test_stream_submit_after_close_raises(cache):
    s = StreamSession(cache.lease(RESNET), fps=30.0, sim_compute_s=0.005)
    s.close()
    with pytest.raises(RuntimeError):
        s.submit_frame(_images(1)[0])


# ----------------------------------------------------------------------
# StreamSession: threaded (wall-clock) mode


def test_stream_threaded_completes_bitwise(cache):
    """The deployment shape: a dispatch thread, wall-clock stamps. Paced
    submissions with a generous deadline complete without drops/misses."""
    eng = cache.get(RESNET)
    imgs = _images(3)
    truth = [np.asarray(eng.run(im)) for im in imgs]
    with StreamSession(cache.lease(RESNET), fps=5.0, deadline_ms=60_000.0,
                       name="rt") as s:
        frames = []
        for im in imgs:
            frames.append(s.submit_frame(im))
            s.flush()  # pace the producer: wait out each frame's compute
        outs = [np.asarray(f.future.result(timeout=600)) for f in frames]
    st = s.stats()
    assert st["completed"] == 3 and st["dropped"] == 0
    assert st["deadline_misses"] == 0
    for t, o in zip(truth, outs):
        assert np.array_equal(t, o)


def test_stream_threaded_skip_to_latest_when_slowed(cache, monkeypatch):
    """Artificially slow the engine: frames queued behind the in-flight
    compute are dropped except the newest (skip-to-latest)."""
    lease = cache.lease(RESNET)
    real = lease.engine.run_stream
    monkeypatch.setattr(lease.engine, "run_stream",
                        lambda buf: (time.sleep(0.15), real(buf))[1])
    with StreamSession(lease, fps=60.0, name="rt-slow") as s:
        frames = [s.submit_frame(im) for im in _images(5)]
        s.flush()
    st = s.stats()
    assert st["dropped"] >= 1  # the burst outran the slowed compute
    assert st["deadline_misses"] >= st["dropped"]
    assert not frames[-1].dropped  # freshest frame survived
    assert frames[-1].future.result(timeout=600) is not None


# ----------------------------------------------------------------------
# acceptance: 4 x 30 fps streams + classify through one shared cache


def test_four_streams_30fps_share_cache_with_classify():
    """The issue's acceptance scenario: 4 concurrent 30 fps simulated
    streams (2 networks, phase-staggered, per-stream leases) share one
    engine cache with on-demand classify traffic; every frame and every
    classify output is bitwise-equal to sequential engine.run calls and
    every stream holds a zero deadline-miss rate."""
    imgs = _images(6)
    nets = ["resnet18", "mobilenet_v2", "resnet18", "mobilenet_v2"]
    with Server(tiny=True, max_batch=4, window_ms=5.0,
                deadline_ms=60_000.0) as server:
        for net in set(nets):
            server.warm(net)
        truth = {net: [np.asarray(server.engines.get(
            tiny_variant(get(net))).run(im)) for im in imgs]
            for net in set(nets)}
        streams = [server.open_stream(net, fps=30.0, sim_compute_s=0.002,
                                      phase_s=0.002 * i)
                   for i, net in enumerate(nets)]
        classify_futs = []

        def classify_client():
            for i, im in enumerate(imgs):
                for net in ("resnet18", "mobilenet_v2"):
                    classify_futs.append((net, i, server.submit(net, im)))

        client = threading.Thread(target=classify_client)
        client.start()
        frames = StreamScheduler(streams).run(len(imgs),
                                              lambda i, k: imgs[k])
        client.join()

        for i, per_stream in enumerate(frames):
            st = streams[i].stats()
            assert st["frames"] == len(imgs) and st["dropped"] == 0
            assert st["deadline_misses"] == 0
            for k, f in enumerate(per_stream):
                assert np.array_equal(
                    truth[nets[i]][k],
                    np.asarray(f.future.result(timeout=600)))
        for net, i, fut in classify_futs:
            assert np.array_equal(truth[net][i],
                                  np.asarray(fut.result(timeout=600)))

        stats = server.stats()
        assert stats["cache"]["misses"] == 2  # one build per network
        assert len(stats["streams"]) == 4
        assert set(stats["cache"]["pinned"]) == {
            engine_key(tiny_variant(get(n))) for n in set(nets)}
        # satellite: on-demand traffic exposes the same deadline telemetry
        for b in stats["networks"].values():
            assert b["queue_depth"] == 0
            assert sum(b["dispatch_causes"].values()) == b["dispatches"]
            assert b["deadline_ms"] == 60_000.0
            assert b["deadline_misses"] == 0
            assert b["deadline_miss_rate"] == 0.0
    assert server.engines.stats()["pinned"] == []  # close released leases


# ----------------------------------------------------------------------
# batcher satellites


def test_batcher_max_batch_rounds_down_to_power_of_two(cache):
    """Satellite: a non-power-of-two max_batch would add one extra traced
    batch shape (the clipped cap); the batcher rounds down instead."""
    eng = cache.get(RESNET)
    with MicroBatcher(eng, max_batch=6, window_ms=1.0) as b:
        assert b.max_batch == 4
    with MicroBatcher(eng, max_batch=8, window_ms=1.0) as b:
        assert b.max_batch == 8
    with MicroBatcher(eng, max_batch=1, window_ms=1.0) as b:
        assert b.max_batch == 1


def test_batcher_stats_concurrent_with_traffic(cache):
    """Satellite: stats() snapshots the dispatch log under a lock, so a
    caller thread hammering it during live traffic never races the loop
    thread's appends."""
    eng = cache.get(RESNET)
    errors = []
    with MicroBatcher(eng, max_batch=4, window_ms=5.0,
                      deadline_ms=60_000.0) as b:
        stop = threading.Event()

        def poll():
            while not stop.is_set():
                try:
                    b.stats()
                except Exception as e:  # pragma: no cover - the regression
                    errors.append(e)
                    return

        poller = threading.Thread(target=poll)
        poller.start()
        futs = [b.submit(im) for im in _images(6)]
        for f in futs:
            f.result(timeout=600)
        stop.set()
        poller.join()
        st = b.stats()
    assert errors == []
    assert st["requests"] == 6
    assert st["queue_depth"] == 0
    assert sum(st["dispatch_causes"].values()) == st["dispatches"]
    assert st["deadline_ms"] == 60_000.0
    assert st["deadline_misses"] == 0 and st["deadline_miss_rate"] == 0.0
