"""The wire tier: framing round-trips, protocol fuzzing (a malformed or
hostile byte stream must produce a typed error — never a hung client,
never a giant allocation), the asyncio client end-to-end over a real
socket (logits bitwise-equal to ``engine.run``), typed rejections
crossing the wire, and the wire-level chaos case (client disconnect
mid-request sheds cleanly with no unresolved futures).

Everything imports from ``repro.serving`` — the public surface carries
the whole protocol.
"""
import asyncio
import socket
import threading
import time

import numpy as np
import pytest

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine
from repro.serving import (
    MAX_FRAME_BYTES,
    AsyncClient,
    BadRequest,
    DeadlineExceeded,
    FaultInjector,
    ProtocolError,
    RequestOptions,
    Server,
    ServerEndpoint,
    ServingOptions,
    decode_request,
    decode_response,
    encode_request,
    encode_response,
    pack_frame,
    read_frame,
    unpack_body,
)


def _reader(data: bytes):
    """A recv_exactly over an in-memory byte string (short read at end)."""
    view = memoryview(data)
    pos = [0]

    def recv_exactly(n):
        chunk = view[pos[0]:pos[0] + n]
        pos[0] += len(chunk)
        return bytes(chunk)

    return recv_exactly


# ---------------------------------------------------------------------------
# framing round-trips


def test_request_frame_round_trip():
    img = np.arange(2 * 3 * 3, dtype=np.float32).reshape(2, 3, 3)
    frame = encode_request(7, "resnet18", img, dtype="bfloat16",
                           deadline_ms=50.0, priority=2)
    header, payload = read_frame(_reader(frame))
    network, image, opts = decode_request(header, payload)
    assert network == "resnet18"
    np.testing.assert_array_equal(image, img)
    assert opts == RequestOptions(dtype="bfloat16", deadline_ms=50.0,
                                  priority=2)
    assert header["id"] == 7


def test_response_frame_round_trip():
    logits = np.linspace(-1, 1, 10, dtype=np.float32)
    ok = encode_response(3, logits=logits)
    rid, status, message, out = decode_response(*read_frame(_reader(ok)))
    assert (rid, status, message) == (3, "ok", None)
    np.testing.assert_array_equal(out, logits)

    err = encode_response(4, status="overloaded", message="queue full")
    rid, status, message, out = decode_response(*read_frame(_reader(err)))
    assert (rid, status, message, out) == (4, "overloaded", "queue full",
                                           None)


def test_multiple_frames_stream_and_clean_eof():
    a = pack_frame({"v": 1, "type": "x", "n": 1})
    b = pack_frame({"v": 1, "type": "x", "n": 2}, b"payload")
    recv = _reader(a + b)
    h1, p1 = read_frame(recv)
    h2, p2 = read_frame(recv)
    assert (h1["n"], p1) == (1, b"")
    assert (h2["n"], p2) == (2, b"payload")
    assert read_frame(recv) is None  # clean EOF at a frame boundary


# ---------------------------------------------------------------------------
# fuzz: malformed byte streams -> typed errors, bounded allocations


def test_truncated_length_prefix_is_protocol_error():
    with pytest.raises(ProtocolError, match="length prefix"):
        read_frame(_reader(b"\x00\x00"))


def test_truncated_body_is_protocol_error():
    frame = pack_frame({"v": 1, "type": "x"}, b"0123456789")
    with pytest.raises(ProtocolError, match="truncated"):
        read_frame(_reader(frame[:-4]))


def test_oversized_length_prefix_refused_without_allocating():
    hostile = (MAX_FRAME_BYTES + 1).to_bytes(4, "big")
    with pytest.raises(ProtocolError, match="MAX_FRAME_BYTES"):
        read_frame(_reader(hostile))


def test_header_overrun_and_bad_json_are_protocol_errors():
    with pytest.raises(ProtocolError, match="overruns"):
        unpack_body(b"\xff\xff")  # header length > body
    with pytest.raises(ProtocolError, match="JSON"):
        unpack_body(b"\x00\x03not-json")
    with pytest.raises(ProtocolError, match="object"):
        unpack_body(b"\x00\x02[]")


@pytest.mark.parametrize("mutate, match", [
    (lambda h: h.update(v=99), "version"),
    (lambda h: h.update(type="mystery"), "frame type"),
    (lambda h: h.update(network=""), "network"),
    (lambda h: h.update(network=None), "network"),
    (lambda h: h.update(image_dtype="float64"), "float32"),
    (lambda h: h.update(shape=[0, 3, 3]), "shape"),
    (lambda h: h.update(shape="nope"), "shape"),
    (lambda h: h.update(shape=[4, 4, 3]), "payload"),  # size mismatch
    (lambda h: h.update(dtype=7), "dtype"),
    (lambda h: h.update(deadline_ms="soon"), "deadline_ms"),
])
def test_malformed_request_headers_are_bad_request(mutate, match):
    img = np.ones((2, 3, 3), dtype=np.float32)
    header, payload = read_frame(_reader(encode_request(1, "net", img)))
    mutate(header)
    with pytest.raises(BadRequest, match=match):
        decode_request(header, payload)


# ---------------------------------------------------------------------------
# end-to-end over a real socket


RESNET = tiny_variant(get("resnet18"))


@pytest.fixture(scope="module")
def endpoint():
    server = Server(tiny=True, options=ServingOptions(
        max_batch=4, window_ms=2.0))
    server.warm("resnet18")  # build outside every test's clock
    with server, ServerEndpoint(server) as ep:
        yield ep


def test_async_client_bitwise_equal_to_engine_run(endpoint):
    import jax

    engine = InferenceEngine(RESNET)
    imgs = [np.asarray(jax.random.normal(jax.random.key(i), (32, 32, 3)))
            for i in range(4)]
    truth = [np.asarray(engine.run(im)) for im in imgs]

    async def go():
        async with await AsyncClient.connect(*endpoint.address) as client:
            return await asyncio.gather(
                *(client.classify("resnet18", im) for im in imgs))

    outs = asyncio.run(go())
    for got, want in zip(outs, truth):
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, want)


def test_unknown_network_is_typed_error_not_a_hang(endpoint):
    async def go():
        async with await AsyncClient.connect(*endpoint.address) as client:
            with pytest.raises(BadRequest):
                await asyncio.wait_for(
                    client.classify("not-a-network",
                                    np.ones((32, 32, 3), np.float32)),
                    timeout=30)
            # the connection survives a bad request: reuse it
            out = await asyncio.wait_for(
                client.classify("resnet18",
                                np.zeros((32, 32, 3), np.float32)),
                timeout=120)
            assert out.ndim == 1

    asyncio.run(go())


def test_bad_dtype_is_typed_error_not_a_hang(endpoint):
    async def go():
        async with await AsyncClient.connect(*endpoint.address) as client:
            with pytest.raises(BadRequest):
                await asyncio.wait_for(
                    client.classify(
                        "resnet18", np.ones((32, 32, 3), np.float32),
                        options=RequestOptions(dtype="float7")),
                    timeout=30)

    asyncio.run(go())


def test_deadline_exceeded_travels_as_typed_status():
    """A request shed at dequeue server-side re-raises as the SAME typed
    exception in the async client — remote callers see in-process error
    semantics."""
    faults = FaultInjector().delay_from("dispatch", 0, seconds=0.15)
    server = Server(tiny=True, options=ServingOptions(
        max_batch=1, window_ms=0.0, faults=faults))
    server.warm("resnet18")

    async def go(address):
        async with await AsyncClient.connect(*address) as client:
            img = np.ones((32, 32, 3), np.float32)
            first = asyncio.create_task(client.classify("resnet18", img))
            await asyncio.sleep(0.05)  # first is mid-dispatch
            # queued behind a 0.15s dispatch with a 1ms budget: must shed
            with pytest.raises(DeadlineExceeded):
                await asyncio.wait_for(
                    client.classify("resnet18", img,
                                    options=RequestOptions(deadline_ms=1.0)),
                    timeout=30)
            out = await asyncio.wait_for(first, timeout=120)
            assert out.ndim == 1

    with server, ServerEndpoint(server) as ep:
        asyncio.run(go(ep.address))


def test_client_disconnect_mid_request_sheds_cleanly():
    """The wire-level chaos case: a client that vanishes with requests in
    flight must not leave unresolved futures — queued work sheds at
    dequeue, the dispatch in flight completes into the void, and the
    server keeps serving."""
    faults = FaultInjector().delay_from("dispatch", 0, seconds=0.2)
    server = Server(tiny=True, options=ServingOptions(
        max_batch=1, window_ms=0.0, faults=faults))
    server.warm("resnet18")
    with server, ServerEndpoint(server) as ep:
        img = np.ones((32, 32, 3), np.float32)
        sock = socket.create_connection(ep.address)
        sock.sendall(encode_request(0, "resnet18", img))
        sock.sendall(encode_request(1, "resnet18", img))
        time.sleep(0.08)  # request 0 is mid-dispatch, request 1 queued
        sock.close()      # vanish

        def batcher_stats():
            nets = server.stats()["networks"]
            return next(iter(nets.values())) if nets else None

        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline:
            b = batcher_stats()
            if b and b["shed"]["cancelled"] >= 1 and b["queue_depth"] == 0:
                break
            time.sleep(0.02)
        b = batcher_stats()
        assert b["shed"]["cancelled"] >= 1  # the queued request shed
        assert b["queue_depth"] == 0        # nothing left dangling

        # and the endpoint still serves new clients afterwards
        async def go():
            async with await AsyncClient.connect(*ep.address) as client:
                return await asyncio.wait_for(
                    client.classify("resnet18", img), timeout=120)

        assert asyncio.run(go()).ndim == 1
        deadline = time.perf_counter() + 5
        while ep.stats()["connections"] and time.perf_counter() < deadline:
            time.sleep(0.02)  # server-side reader notices the EOF async
        assert ep.stats()["connections"] == 0


def test_server_close_fails_pending_awaits_not_hangs():
    """Endpoint torn down under a waiting client: the await fails with a
    connection error instead of hanging."""
    server = Server(tiny=True, options=ServingOptions(
        max_batch=1, window_ms=0.0))
    server.warm("resnet18")
    ep = ServerEndpoint(server)

    async def go():
        client = await AsyncClient.connect(*ep.address)
        try:
            closer = threading.Timer(0.15, ep.close)
            closer.start()
            # the endpoint closes the conn under us mid-wait; depending
            # on timing the request may also complete first — both are
            # fine, a hang is not
            try:
                await asyncio.wait_for(
                    client.classify("resnet18",
                                    np.ones((32, 32, 3), np.float32)),
                    timeout=30)
            except (ConnectionError, ProtocolError):
                pass
            closer.join()
        finally:
            await client.close()

    try:
        asyncio.run(go())
    finally:
        ep.close()
        server.close()
