"""Sharding rules unit tests (pure logic — no multi-device needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get
from repro.sharding.rules import DEFAULT_RULES, logical_spec, rules_for


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh over fake devices — only .shape/.axis_names used."""
    devs = np.empty(shape, dtype=object)
    it = np.nditer(devs, flags=["multi_index", "refs_ok"])
    class D:  # minimal device stand-in
        def __init__(self, i):
            self.id = i
    i = 0
    for _ in it:
        devs[it.multi_index] = D(i)
        i += 1
    return Mesh(devs, axes)


MESH = _fake_mesh()


def test_divisible_dims_get_sharded():
    spec = logical_spec(("vocab", "embed_fsdp"), (49664, 4096),
                        DEFAULT_RULES, MESH)
    assert spec == P("model", "data")


def test_indivisible_dims_fall_back_to_replication():
    # 8 KV heads on a 16-way model axis -> replicated (Megatron fallback)
    spec = logical_spec(("embed_fsdp", "kv_heads", None), (4096, 8, 128),
                        DEFAULT_RULES, MESH)
    assert spec == P("data", None, None)


def test_mesh_axis_used_once_per_spec():
    spec = logical_spec(("seq_shard", "vocab_act"), (4096, 49664),
                        DEFAULT_RULES, MESH)
    assert spec == P("model", None)  # first claimant wins


def test_joint_batch_axis():
    mesh3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = logical_spec(("batch", "seq"), (256, 4096), DEFAULT_RULES, mesh3)
    assert spec == P(("pod", "data"), None)


def test_param_policy_replicated():
    cfg = get("granite-8b").replace(param_sharding="replicated")
    rules = rules_for(cfg, MESH)
    spec = logical_spec(("embed_fsdp", "d_ff"), (4096, 14336), rules, MESH)
    assert spec == P(None, None)


def test_param_policy_tp_only():
    cfg = get("granite-8b").replace(param_sharding="tp")
    rules = rules_for(cfg, MESH)
    spec = logical_spec(("embed_fsdp", "d_ff"), (4096, 14336), rules, MESH)
    assert spec == P(None, "model")


def test_decode_seq_one_replicates():
    spec = logical_spec(("batch", "seq_shard", None, None), (128, 1, 32, 64),
                        DEFAULT_RULES, MESH)
    assert spec == P("data", None, None, None) or spec[1] is None


def test_production_mesh_axes():
    """make_production_mesh contract (shape + names), via spec inspection.

    The real 512-device build is exercised by launch/dryrun.py; here we
    assert the function's constants so a refactor can't silently change
    the production topology.
    """
    import inspect

    from repro.launch import mesh as mesh_mod

    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
