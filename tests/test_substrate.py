"""Substrate tests: optimizer, schedules, data determinism, checkpointing,
fault tolerance, elastic re-mesh, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.optim import adafactor, adamw, schedule
from repro.runtime import (StragglerWatch, TransientFailure, elastic_remesh,
                           resilient_train)


# ---------------------------------------------------------------- optim

def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    def grads_of(p):
        return {"w": 2 * (p["w"] - target)}
    return params, grads_of, target


def test_adamw_converges():
    params, grads_of, target = _quadratic_problem()
    state = adamw.init(params)
    for _ in range(300):
        params, state = adamw.update(grads_of(params), state, params,
                                     lr=0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_bf16_states():
    params = {"w": jnp.ones((4, 4))}
    state = adamw.init(params, state_dtype="bfloat16")
    assert state["m"]["w"].dtype == jnp.bfloat16
    newp, state = adamw.update({"w": jnp.ones((4, 4))}, state, params, lr=0.1)
    assert newp["w"].dtype == params["w"].dtype


def test_adafactor_converges_and_factors():
    params = {"w": jnp.zeros((8, 6)), "b": jnp.zeros(6)}
    target = jax.random.normal(jax.random.key(0), (8, 6))
    state = adafactor.init(params)
    assert state["vr"]["w"].shape == (8,)      # factored row stats
    assert state["vc"]["w"].shape == (6,)
    for _ in range(400):
        g = {"w": 2 * (params["w"] - target), "b": params["b"] * 0}
        params, state = adafactor.update(g, state, params, lr=0.05)
    assert float(jnp.abs(params["w"] - target).mean()) < 0.1


def test_optimizer_state_specs_match_params():
    from repro.configs import get, tiny_variant
    from repro.launch.steps import init_state, state_specs

    cfg = tiny_variant(get("granite-8b"))
    st = init_state(cfg, 0)
    specs = state_specs(cfg)
    flat_s = jax.tree.leaves(specs)
    flat_v = jax.tree.leaves(st)
    assert len(flat_s) == len(flat_v)


def test_schedule_shapes():
    s0 = schedule.warmup_cosine(jnp.asarray(0), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100)
    s10 = schedule.warmup_cosine(jnp.asarray(10), peak_lr=1e-3,
                                 warmup_steps=10, total_steps=100)
    s100 = schedule.warmup_cosine(jnp.asarray(100), peak_lr=1e-3,
                                  warmup_steps=10, total_steps=100)
    assert float(s0) == 0.0
    assert abs(float(s10) - 1e-3) < 1e-9
    assert float(s100) < 2e-4


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 100.0}
    clipped, norm = schedule.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


# ----------------------------------------------------------------- data

def test_pipeline_deterministic_skip_ahead():
    p1 = TokenPipeline(1000, 16, 4, seed=7)
    p2 = TokenPipeline(1000, 16, 4, seed=7)
    # restart at step 5 must regenerate the same batch with no state replay
    b1 = p1.batch(5)
    for _ in range(3):
        p2.batch(0)  # unrelated reads do not perturb determinism
    b2 = p2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(p1.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))


def test_pipeline_labels_shifted():
    p = TokenPipeline(50, 8, 2, seed=1)
    b = p.batch(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


# ----------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.asarray(3)}}
    mgr.save(10, tree)
    mgr.save(20, tree)
    mgr.save(30, tree)
    assert mgr.all_steps() == [20, 30]  # keep=2 garbage collection
    step, restored = mgr.restore()
    assert step == 30
    np.testing.assert_array_equal(restored["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))


def test_checkpoint_integrity_detection(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, {"w": jnp.ones(4)})
    shard = next((tmp_path / "step_1").glob("shard_*.npz"))
    shard.write_bytes(shard.read_bytes()[:-7] + b"corrupt")
    with pytest.raises(IOError):
        mgr.restore(1)


def test_checkpoint_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, {"w": jnp.ones(2)})
    torn = tmp_path / "step_2"
    torn.mkdir()
    (torn / "shard_0.npz").write_bytes(b"partial")  # no COMMIT marker
    assert mgr.latest_step() == 1


# ------------------------------------------------------ fault tolerance

def _toy_train_setup(tmp_path):
    params = {"w": jnp.zeros(4)}

    def train_step(state, batch):
        g = state["w"] - batch["tokens"].astype(jnp.float32).mean()
        new = {"w": state["w"] - 0.1 * g}
        return new, {"loss": jnp.sum(g * g)}

    pipe = TokenPipeline(100, 4, 2, seed=3)
    ckpt = CheckpointManager(tmp_path, async_save=False)
    return params, train_step, pipe, ckpt


def test_resilient_train_survives_failures(tmp_path):
    params, train_step, pipe, ckpt = _toy_train_setup(tmp_path)
    boom = {20: True, 35: True}

    def injector(step):
        if boom.pop(step, None):
            raise TransientFailure(f"injected at {step}")

    state, step, failures = resilient_train(
        state=params, train_step=train_step, pipeline=pipe, ckpt=ckpt,
        total_steps=50, ckpt_every=10, max_failures=5, fail_injector=injector)
    assert step == 50 and failures == 2


def test_resilient_train_replays_identically(tmp_path):
    """Crash-and-restore must produce the same final state as no-crash."""
    params, train_step, pipe, ckpt = _toy_train_setup(tmp_path / "a")
    state_ref, _, _ = resilient_train(
        state=params, train_step=train_step, pipeline=pipe, ckpt=ckpt,
        total_steps=30, ckpt_every=5, max_failures=0)

    params, train_step, pipe, ckpt = _toy_train_setup(tmp_path / "b")
    hits = {17: True}

    def injector(step):
        if hits.pop(step, None):
            raise TransientFailure("boom")

    state_ft, _, fails = resilient_train(
        state=params, train_step=train_step, pipeline=pipe, ckpt=ckpt,
        total_steps=30, ckpt_every=5, max_failures=2, fail_injector=injector)
    assert fails == 1
    np.testing.assert_allclose(np.asarray(state_ft["w"]),
                               np.asarray(state_ref["w"]), rtol=1e-6)


def test_straggler_watch_raises():
    w = StragglerWatch(factor=2.0, max_breaches=2, warmup=0)
    for _ in range(6):
        w.observe(0.1)
    w.observe(0.5)
    with pytest.raises(RuntimeError):
        w.observe(0.5)


def test_elastic_remesh_divisibility():
    mesh = elastic_remesh(1, model_dims=[4096, 32, 14336])
    assert mesh.shape["data"] * mesh.shape["model"] == 1
    # degenerate single-device case still builds a named mesh
    assert set(mesh.axis_names) == {"data", "model"}
