"""End-to-end behaviour tests for the paper's system.

1. Single-image CNN inference through the ILP-M engine (the paper's
   deployment scenario) gives the same class scores under every algorithm.
2. A tiny LM trains end-to-end: loss decreases over real optimization steps.
3. Crash-restore-resume training is bit-reproducible vs an uninterrupted run.
4. Serving loop: prefill + iterative decode produces identical tokens to
   teacher-forced greedy decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, tiny_variant
from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.launch import steps
from repro.models import lm
from repro.runtime import TransientFailure, resilient_train


def test_singleimage_inference_consistency():
    from repro.core import InferenceEngine

    cfg = tiny_variant(get("resnet18"))
    eng_ref = InferenceEngine(cfg, algorithm="xla")
    eng_ilpm = InferenceEngine(cfg, params=eng_ref.params, algorithm="ilpm")
    img = jax.random.normal(jax.random.key(0), (32, 32, 3))
    np.testing.assert_allclose(np.asarray(eng_ilpm.run(img)),
                               np.asarray(eng_ref.run(img)), rtol=1e-3,
                               atol=1e-3)


def test_lm_loss_decreases():
    cfg = tiny_variant(get("qwen2-0.5b")).replace(vocab_size=64)
    state = steps.init_state(cfg, 0)
    ts = jax.jit(steps.make_train_step(cfg, peak_lr=3e-3, warmup=5,
                                       total_steps=60))
    pipe = TokenPipeline(16, 16, 8, seed=0)  # tiny vocab -> learnable
    losses = []
    for step in range(25):
        state, m = ts(state, pipe.batch(step))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_crash_resume_bitwise(tmp_path):
    cfg = tiny_variant(get("granite-3-2b")).replace(vocab_size=128,
                                                    num_layers=2)
    pipe = TokenPipeline(cfg.vocab_size, 16, 4, seed=5)
    ts = jax.jit(steps.make_train_step(cfg, peak_lr=1e-3, warmup=2,
                                       total_steps=40))

    def run(tmp, injector=None, max_failures=0):
        state = steps.init_state(cfg, 1)
        ckpt = CheckpointManager(tmp, async_save=False)
        state, step, fails = resilient_train(
            state=state, train_step=ts, pipeline=pipe, ckpt=ckpt,
            total_steps=12, ckpt_every=4, max_failures=max_failures,
            fail_injector=injector)
        return state, fails

    ref_state, _ = run(tmp_path / "ref")
    hits = {9: True}

    def injector(step):
        if hits.pop(step, None):
            raise TransientFailure("chaos-monkey")

    ft_state, fails = run(tmp_path / "ft", injector, max_failures=2)
    assert fails == 1
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(ft_state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_prefill_then_decode_matches_teacher_forcing():
    cfg = tiny_variant(get("granite-8b")).replace(vocab_size=96)
    params = steps.init_state(cfg, 3)["params"]
    B, S, STEPS, CACHE = 2, 8, 4, 16
    prompt = jax.random.randint(jax.random.key(9), (B, S), 0, cfg.vocab_size)

    # serving loop
    logits, caches, _ = lm.forward(params, cfg, prompt, mode="prefill",
                                   cache_len=CACHE)
    toks = [jnp.argmax(logits[:, -1, : cfg.vocab_size], -1)]
    for i in range(STEPS - 1):
        logits, caches, _ = lm.forward(params, cfg, toks[-1][:, None],
                                       mode="decode", caches=caches,
                                       pos=S + i)
        toks.append(jnp.argmax(logits[:, 0, : cfg.vocab_size], -1))
    served = jnp.stack(toks, 1)

    # teacher-forced reference: feed the served tokens, check argmax agrees
    full = jnp.concatenate([prompt, served], axis=1)
    ref_logits, _, _ = lm.forward(params, cfg, full, mode="train")
    ref_tokens = jnp.argmax(
        ref_logits[:, S - 1: S - 1 + STEPS, : cfg.vocab_size], -1)
    np.testing.assert_array_equal(np.asarray(served), np.asarray(ref_tokens))
