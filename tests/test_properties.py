"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref

KEY = jax.random.key(42)
FAST = dict(max_examples=12, deadline=None, derandomize=True)


def _conv_case(draw_h, draw_w, draw_c, draw_k, seed):
    x = jax.random.normal(jax.random.fold_in(KEY, seed),
                          (1, draw_h, draw_w, draw_c))
    w = jax.random.normal(jax.random.fold_in(KEY, seed + 1),
                          (3, 3, draw_c, draw_k))
    return x, w


@settings(**FAST)
@given(h=st.integers(4, 12), w=st.integers(4, 12), c=st.integers(1, 16),
       k=st.integers(1, 16), seed=st.integers(0, 100))
def test_conv_linearity(h, w, c, k, seed):
    """conv(a·x1 + x2) == a·conv(x1) + conv(x2) — ILP-M is linear."""
    x1, wgt = _conv_case(h, w, c, k, seed)
    x2 = jax.random.normal(jax.random.fold_in(KEY, seed + 2), x1.shape)
    a = 1.7
    lhs = ref.ilpm_conv(ref.pad_same(a * x1 + x2, 3, 3), wgt)
    rhs = a * ref.ilpm_conv(ref.pad_same(x1, 3, 3), wgt) \
        + ref.ilpm_conv(ref.pad_same(x2, 3, 3), wgt)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3,
                               atol=1e-3)


@settings(**FAST)
@given(h=st.integers(6, 12), w=st.integers(6, 12), c=st.integers(1, 8),
       k=st.integers(1, 8), seed=st.integers(0, 100))
def test_conv_translation_equivariance(h, w, c, k, seed):
    """Shifting the (VALID-conv) input shifts the output."""
    x, wgt = _conv_case(h, w, c, k, seed)
    y = ref.ilpm_conv(x, wgt)                      # VALID: x is 'pre-padded'
    xs = jnp.roll(x, 1, axis=2)
    ys = ref.ilpm_conv(xs, wgt)
    np.testing.assert_allclose(np.asarray(y[:, :, : w - 3]),
                               np.asarray(ys[:, :, 1: w - 2]), rtol=1e-3,
                               atol=1e-3)


@settings(**FAST)
@given(h=st.sampled_from([6, 8, 10]), w=st.sampled_from([6, 8, 10]),
       c=st.integers(1, 12), k=st.integers(1, 12), seed=st.integers(0, 50))
def test_all_algorithms_agree(h, w, c, k, seed):
    """The five algorithms compute the same convolution (paper's premise)."""
    x, wgt = _conv_case(h, w, c, k, seed)
    xp = ref.pad_same(x, 3, 3)
    ys = {name: np.asarray(ops.ALGORITHMS[name](xp, wgt, impl="jnp"))
          for name in ops.DENSE_ALGORITHMS}
    base = ys.pop("ilpm")
    scale = max(float(np.abs(base).max()), 1e-3)
    for name, y in ys.items():
        np.testing.assert_allclose(y, base, rtol=2e-3, atol=2e-4 * scale,
                                    err_msg=name)


@settings(**FAST)
@given(sq=st.sampled_from([4, 16, 33]), sk=st.sampled_from([8, 64, 130]),
       h=st.sampled_from([1, 2, 4]), seed=st.integers(0, 50),
       chunk=st.sampled_from([8, 16, 64]))
def test_attention_chunked_equals_full(sq, sk, h, seed, chunk):
    """Online-softmax chunking is exact (any chunk size)."""
    from repro.models.layers import _attend_chunked, _attend_full

    kk = jax.random.fold_in(KEY, seed)
    q = jax.random.normal(kk, (2, sq, h, 8))
    k = jax.random.normal(jax.random.fold_in(kk, 1), (2, sk, h, 8))
    v = jax.random.normal(jax.random.fold_in(kk, 2), (2, sk, h, 8))
    # every query must see >= 1 key (fully-masked rows are out of contract)
    qp = jnp.broadcast_to(jnp.arange(sq) + max(sk - sq, 0), (2, sq))
    kp = jnp.broadcast_to(jnp.arange(sk), (2, sk))
    full = _attend_full(q, k, v, causal=True, q_pos=qp, kv_pos=kp, scale=0.35)
    ck = _attend_chunked(q, k, v, causal=True, q_pos=qp, kv_pos=kp,
                         scale=0.35, chunk=chunk)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(full), rtol=2e-5,
                               atol=2e-5)


@settings(**FAST)
@given(l=st.sampled_from([32, 48, 96]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 50))
def test_ssd_chunk_invariance(l, chunk, seed):
    """SSD output must not depend on the chunk size (algorithm invariant)."""
    from repro.models.ssm import ssd_chunked

    kk = jax.random.fold_in(KEY, seed)
    B, G, Hg, P, N = 1, 1, 2, 4, 8
    x = jax.random.normal(kk, (B, l, G, Hg, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(kk, 1),
                                           (B, l, G, Hg)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(kk, 2), (G, Hg)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(kk, 3), (B, l, G, N))
    C = jax.random.normal(jax.random.fold_in(kk, 4), (B, l, G, N))
    y1, s1 = ssd_chunked(x, dt, A, Bm, C, chunk)
    y2, s2 = ssd_chunked(x, dt, A, Bm, C, l)  # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3,
                               atol=2e-3)


def test_ssd_equals_naive_recurrence():
    """Chunked SSD == step-by-step recurrent scan (duality check)."""
    from repro.models.ssm import ssd_chunked

    kk = jax.random.fold_in(KEY, 9)
    B, L, G, Hg, P, N = 1, 24, 1, 2, 3, 4
    x = jax.random.normal(kk, (B, L, G, Hg, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(kk, 1),
                                           (B, L, G, Hg)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(kk, 2), (G, Hg)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(kk, 3), (B, L, G, N))
    C = jax.random.normal(jax.random.fold_in(kk, 4), (B, L, G, N))
    y, s_final = ssd_chunked(x, dt, A, Bm, C, 8)
    # naive recurrence
    s = np.zeros((B, G, Hg, P, N))
    ys = []
    for t in range(L):
        dA = np.exp(np.asarray(dt[:, t] * A))            # (B,G,Hg)
        upd = np.einsum("bgh,bgn,bghp->bghpn", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(x[:, t]))
        s = s * dA[..., None, None] + upd
        ys.append(np.einsum("bgn,bghpn->bghp", np.asarray(C[:, t]), s))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_final), s, rtol=2e-3, atol=2e-3)


@settings(**FAST)
@given(b=st.sampled_from([1, 2]), s=st.sampled_from([8, 16]),
       seed=st.integers(0, 30), cf=st.sampled_from([4.0, 8.0]))
def test_moe_sorted_equals_dense(b, s, seed, cf):
    """Sort-based dispatch == dense GShard dispatch at high capacity."""
    from repro.configs import get, tiny_variant
    from repro.models import layers as L
    from repro.models.spec import init_params

    cfg = tiny_variant(get("granite-moe-3b-a800m")).replace(
        capacity_factor=cf, num_shared_experts=0)
    p = init_params(L.moe_specs(cfg), seed, "float32")
    x = jax.random.normal(jax.random.fold_in(KEY, seed),
                          (b, s, cfg.d_model)) * 0.3
    y_dense, _ = L.moe(p, cfg.replace(moe_dispatch="dense"), x)
    logits = jnp.einsum("bse,ef->bsf", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    y_sorted = L._moe_scatter_dispatch(p, cfg, x, idx, gate, None)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_sorted),
                               rtol=1e-4, atol=1e-5)


@settings(**FAST)
@given(seed=st.integers(0, 100))
def test_rope_preserves_norm(seed):
    """Rotary embedding is a rotation: vector norms are invariant."""
    from repro.models.layers import rope

    x = jax.random.normal(jax.random.fold_in(KEY, seed), (2, 6, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)


@settings(**FAST)
@given(seed=st.integers(0, 100))
def test_rope_relative_property(seed):
    """<rope(q,m), rope(k,n)> depends only on (m - n)."""
    from repro.models.layers import rope

    q = jax.random.normal(jax.random.fold_in(KEY, seed), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, seed + 1), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = rope(q, jnp.full((1, 1), m), 10000.0)
        kn = rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3
    assert abs(dot_at(4, 0) - dot_at(14, 10)) < 1e-3


@settings(**FAST)
@given(seed=st.integers(0, 100), shape=st.sampled_from([(8,), (4, 6), (3, 5, 7)]))
def test_compression_error_feedback_bound(seed, shape):
    """int8 EF quantization: residual bounded by scale/2; codes in range."""
    from repro.optim.compression import ef_compress, dequantize

    g = jax.random.normal(jax.random.fold_in(KEY, seed), shape) * 3.0
    err = jnp.zeros(shape)
    codes, scale, new_err = ef_compress(g, err)
    assert int(jnp.abs(codes).max()) <= 127
    np.testing.assert_allclose(
        np.asarray(dequantize(codes, scale) + new_err), np.asarray(g),
        rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(new_err).max()) <= float(scale) * 0.5 + 1e-6


@settings(**FAST)
@given(seed=st.integers(0, 50))
def test_ce_loss_matches_log_softmax(seed):
    """The sharded-vocab-safe CE equals the textbook formula."""
    from repro.launch.steps import _ce_loss

    kk = jax.random.fold_in(KEY, seed)
    logits = jax.random.normal(kk, (2, 5, 17)) * 3
    labels = jax.random.randint(jax.random.fold_in(kk, 1), (2, 5), 0, 17)
    labels = labels.at[0, 0].set(-100)  # ignore index
    want_ll = jax.nn.log_softmax(logits, -1)
    mask = labels >= 0
    want = -(jnp.take_along_axis(want_ll, jnp.maximum(labels, 0)[..., None],
                                 axis=-1)[..., 0] * mask).sum() / mask.sum()
    got = _ce_loss(logits, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
