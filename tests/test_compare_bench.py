"""Bench-regression gate tests: tools/compare_bench.py must catch an
injected xla fallback and a proxy slowdown, and stay quiet otherwise."""
import copy
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import compare_bench  # noqa: E402  (needs the tools/ path hook above)


def _payload(algorithms=("ilpm", "pointwise"), proxy=(0.10, 0.05),
             est=(1e-4, 5e-5)):
    return {
        "config": "resnet18-tiny",
        "n_sites": len(algorithms),
        "xla_sites": [n for n, a in zip("ab", algorithms) if a == "xla"],
        "layers": [
            {"layer": name, "algorithm": alg, "est_time_s": e,
             "interpret_time_s": p}
            for name, alg, e, p in zip("ab", algorithms, est, proxy)
        ],
    }


def test_clean_comparison_passes():
    base = _payload()
    problems, _ = compare_bench.compare(base, copy.deepcopy(base))
    assert problems == []


def test_injected_xla_fallback_fails():
    base = _payload()
    cand = copy.deepcopy(base)
    cand["layers"][0]["algorithm"] = "xla"
    problems, _ = compare_bench.compare(base, cand)
    assert any("xla escape hatch" in p for p in problems)


def test_algorithm_change_between_tuned_kernels_is_allowed():
    base = _payload()
    cand = copy.deepcopy(base)
    cand["layers"][0]["algorithm"] = "direct"  # tuner re-decided: fine
    problems, notes = compare_bench.compare(base, cand)
    assert problems == []
    assert any("ilpm -> direct" in n for n in notes)


def test_proxy_slowdown_beyond_tolerance_fails():
    base = _payload()
    cand = copy.deepcopy(base)
    for l in cand["layers"]:
        l["interpret_time_s"] *= 1.40  # > 25% default tolerance
    problems, _ = compare_bench.compare(base, cand)
    assert any("interpret-proxy" in p for p in problems)
    # within tolerance: clean
    for l in cand["layers"]:
        l["interpret_time_s"] = l["interpret_time_s"] / 1.40 * 1.10
    problems, _ = compare_bench.compare(base, cand)
    assert problems == []


def test_new_and_removed_layers_are_skipped_not_failed():
    base = _payload()
    cand = copy.deepcopy(base)
    cand["layers"].append({"layer": "c", "algorithm": "xla",
                           "est_time_s": 1.0, "interpret_time_s": 1.0})
    problems, notes = compare_bench.compare(base, cand)
    assert problems == []  # a *new* xla site isn't a regression of a
    assert any("new layers" in n for n in notes)  # tuned one (CI's
    # separate xla_sites assert still rejects it outright)


def _blocks_payload(fused=(True, True), est=(4e5, 6e5), per_layer=(7e5, 9e5)):
    p = _payload()
    p["blocks"] = [
        {"block": name, "kind": "inverted_residual", "fused": f,
         "algorithm": "fused_inverted_residual" if f else None,
         "est_bytes": int(e) if f else None,
         "per_layer_est_bytes": int(pl)}
        for name, f, e, pl in zip(("s0b0", "s1b0"), fused, est, per_layer)
    ]
    return p


def test_fused_block_clean_comparison_passes():
    base = _blocks_payload()
    problems, _ = compare_bench.compare(base, copy.deepcopy(base))
    assert problems == []


def test_previously_fused_block_regressing_to_per_layer_fails():
    base = _blocks_payload()
    cand = _blocks_payload(fused=(True, False))
    problems, _ = compare_bench.compare(base, cand)
    assert any("previously-fused block site regressed" in p
               for p in problems)


def test_newly_fused_block_is_noted_not_failed():
    base = _blocks_payload(fused=(True, False))
    cand = _blocks_payload()
    problems, notes = compare_bench.compare(base, cand)
    assert problems == []
    assert any("newly fused" in n for n in notes)


def test_fused_row_must_save_bytes():
    """The charging invariant, gated in CI: a fused row whose byte
    estimate is not strictly below the per-layer constituent sum means
    the cost model's saved-round-trip accounting broke."""
    base = _blocks_payload()
    cand = _blocks_payload(est=(4e5, 9e5))  # == per_layer sum: no saving
    problems, _ = compare_bench.compare(base, cand)
    assert any("not" in p and "per-layer" in p for p in problems)


def test_pre_fusion_baseline_without_blocks_section_is_tolerated():
    base = _payload()  # v1 artifact: no "blocks" key at all
    cand = _blocks_payload()
    problems, _ = compare_bench.compare(base, cand)
    assert problems == []


def test_conv_committed_baseline_block_invariants():
    """The committed conv baseline carries fused-block rows, at least one
    site is fused, and every fused row's estimate is strictly below its
    per-layer sum — the acceptance bar, pinned on the artifact CI diffs
    against."""
    baseline = REPO / "benchmarks" / "baseline" / "BENCH_conv.json"
    d = json.loads(baseline.read_text())
    blocks = d.get("blocks", [])
    assert blocks, "baseline predates fused-block rows"
    fused = [b for b in blocks if b["fused"]]
    assert fused
    for b in fused:
        assert b["est_bytes"] < b["per_layer_est_bytes"], b["block"]
    assert d["fused_sites"] == [b["block"] for b in fused]
    problems, _ = compare_bench.compare(d, copy.deepcopy(d))
    assert problems == []


def _stream_payload(steady_miss=0.0, overload_miss=0.8, drop_rate=0.3):
    def scenario(miss, drops):
        return {"sim_compute_ms": 8.0,
                "aggregate": {"frames": 180, "completed": 150,
                              "dropped": int(drops * 180),
                              "drop_rate": drops,
                              "deadline_misses": int(miss * 180),
                              "deadline_miss_rate": miss}}
    return {"kind": "streaming",
            "scenarios": {"steady": scenario(steady_miss, 0.0),
                          "overload": scenario(overload_miss, drop_rate)}}


def test_streaming_clean_comparison_passes():
    base = _stream_payload()
    problems, _ = compare_bench.compare_streaming(base, copy.deepcopy(base))
    assert problems == []


def test_streaming_miss_rate_regression_fails():
    base = _stream_payload()
    cand = _stream_payload(steady_miss=0.2)
    problems, _ = compare_bench.compare_streaming(base, cand)
    assert any("steady: deadline_miss_rate regressed" in p for p in problems)


def test_streaming_drop_rate_regression_fails_and_tolerance():
    base = _stream_payload()
    cand = _stream_payload(drop_rate=0.4)
    problems, _ = compare_bench.compare_streaming(base, cand)
    assert any("overload: drop_rate regressed" in p for p in problems)
    problems, notes = compare_bench.compare_streaming(
        base, cand, miss_tolerance=0.2)
    assert problems == []  # within the loosened tolerance: noted, not fatal
    assert any("drop_rate changed" in n for n in notes)


def test_streaming_improvement_is_noted_not_failed():
    base = _stream_payload(overload_miss=0.8)
    cand = _stream_payload(overload_miss=0.5)
    problems, notes = compare_bench.compare_streaming(base, cand)
    assert problems == []
    assert any("deadline_miss_rate changed" in n for n in notes)


def test_streaming_cli_detects_kind_and_gates(tmp_path):
    """The CLI auto-detects streaming payloads, exits 1 on a miss-rate
    regression or an artifact-kind mismatch, 0 on a clean match."""
    script = REPO / "tools" / "compare_bench.py"
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_stream_payload()))
    ok = subprocess.run([sys.executable, str(script), str(base), str(base)],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    assert "2 scenarios" in ok.stdout
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_stream_payload(steady_miss=0.5)))
    r = subprocess.run([sys.executable, str(script), str(base), str(bad)],
                       capture_output=True, text=True)
    assert r.returncode == 1 and "deadline_miss_rate regressed" in r.stderr
    conv = REPO / "benchmarks" / "baseline" / "BENCH_conv.json"
    mixed = subprocess.run([sys.executable, str(script), str(base),
                            str(conv)], capture_output=True, text=True)
    assert mixed.returncode == 1
    assert "different artifact kinds" in mixed.stderr


def test_streaming_committed_baseline_vs_itself_is_clean():
    baseline = REPO / "benchmarks" / "baseline" / "BENCH_streaming.json"
    d = json.loads(baseline.read_text())
    problems, _ = compare_bench.compare_streaming(d, copy.deepcopy(d))
    assert problems == []
    # the committed steady scenario must hold a zero miss rate: that is
    # the invariant the CI gate pins
    assert d["scenarios"]["steady"]["aggregate"]["deadline_miss_rate"] == 0.0
    assert d["scenarios"]["overload"]["aggregate"]["dropped"] > 0


def _quant_payload(bf16_agree=1.0, bf16_err=7e-3, bf16_xla=(),
                   bf16_est=1.2e-5):
    def row(dtype, agree, err, est, xla=(), **extra):
        return {"dtype": dtype, "n_images": 8, "top1_agreement": agree,
                "logit_rel_err": err, "est_time_s": est,
                "est_bytes": int(est * 8e11), "weight_bytes": 20_000_000,
                "xla_sites": list(xla), **extra}
    return {"kind": "quant", "config": "resnet18-tiny", "n_images": 8,
            "rows": [row("float32", 1.0, 0.0, 2.4e-5),
                     row("bfloat16", bf16_agree, bf16_err, bf16_est,
                         bf16_xla),
                     row("int8", 1.0, 1.7e-2, 2.4e-5,
                         quantized_sites=12)]}


def test_quant_clean_comparison_passes():
    base = _quant_payload()
    problems, _ = compare_bench.compare_quant(base, copy.deepcopy(base))
    assert problems == []


def test_quant_agreement_drop_fails_within_tolerance_noted():
    base = _quant_payload()
    cand = _quant_payload(bf16_agree=0.625)  # 3 of 8 images flipped
    problems, _ = compare_bench.compare_quant(base, cand)
    assert any("top-1 agreement regressed" in p for p in problems)
    cand = _quant_payload(bf16_agree=0.875)  # 1 of 8: within tolerance
    problems, notes = compare_bench.compare_quant(base, cand)
    assert problems == []
    assert any("agreement changed" in n for n in notes)


def test_quant_logit_error_blowup_fails():
    base = _quant_payload()
    cand = _quant_payload(bf16_err=7e-3 * 3)  # > 2x baseline
    problems, _ = compare_bench.compare_quant(base, cand)
    assert any("logit rel err blew up" in p for p in problems)
    # fp32's ~0 baseline row tolerates sub-floor noise (no 2x-of-zero trap)
    cand = copy.deepcopy(base)
    cand["rows"][0]["logit_rel_err"] = 5e-5
    problems, _ = compare_bench.compare_quant(base, cand)
    assert problems == []


def test_quant_new_xla_fallback_in_low_precision_fails():
    base = _quant_payload()
    cand = _quant_payload(bf16_xla=("stem",))
    problems, _ = compare_bench.compare_quant(base, cand)
    assert any("newly fell back to xla" in p for p in problems)


def test_quant_est_time_regression_fails():
    base = _quant_payload()
    cand = _quant_payload(bf16_est=1.2e-5 * 1.5)
    problems, _ = compare_bench.compare_quant(base, cand)
    assert any("est_time regressed" in p for p in problems)


def test_quant_cli_detects_kind(tmp_path):
    script = REPO / "tools" / "compare_bench.py"
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_quant_payload()))
    ok = subprocess.run([sys.executable, str(script), str(base), str(base)],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    assert "3 precision rows" in ok.stdout
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_quant_payload(bf16_xla=("stem",))))
    r = subprocess.run([sys.executable, str(script), str(base), str(bad)],
                       capture_output=True, text=True)
    assert r.returncode == 1 and "newly fell back to xla" in r.stderr
    mixed = subprocess.run([sys.executable, str(script), str(base),
                            str(REPO / "benchmarks" / "baseline" /
                                "BENCH_conv.json")],
                           capture_output=True, text=True)
    assert mixed.returncode == 1
    assert "different artifact kinds" in mixed.stderr


def test_quant_committed_baseline_vs_itself_is_clean():
    baseline = REPO / "benchmarks" / "baseline" / "BENCH_quant.json"
    d = json.loads(baseline.read_text())
    problems, _ = compare_bench.compare_quant(d, copy.deepcopy(d))
    assert problems == []
    rows = {r["dtype"]: r for r in d["rows"]}
    # the invariants the CI sanity step pins, pinned on the baseline too
    assert {"float32", "bfloat16", "float16", "int8"} <= rows.keys()
    for r in rows.values():
        assert r["xla_sites"] == []
    assert rows["bfloat16"]["est_time_s"] < rows["float32"]["est_time_s"]
    assert rows["int8"]["weight_bytes"] < rows["float32"]["weight_bytes"]


def _serving_payload(shed_rate=0.8, unresolved=0, p95=0.25, bound=0.34,
                     throughput=6.8, sweep_rates=(0.0, 0.0, 0.2),
                     sweep_p95=(0.03, 0.04, 0.15), sweep_bound=0.175,
                     sweep_unresolved=0):
    offered = 80
    shed = int(shed_rate * offered)
    rungs = [{"load_factor": lf, "offered": 16,
              "accepted": 16 - int(r * 16), "shed": int(r * 16),
              "shed_rate": r, "unresolved": sweep_unresolved,
              "p50_s": p * 0.8, "p95_s": p, "p99_s": p * 1.1}
             for lf, r, p in zip((0.25, 0.5, 2.0), sweep_rates, sweep_p95)]
    return {
        "kind": "serving",
        "networks": ["resnet18", "mobilenet_v2"],
        "scenarios": {
            "steady": {"requests": 24, "throughput_rps": throughput,
                       "wall_s": 24 / throughput},
            "overload": {"offered": offered, "accepted": offered - shed,
                         "shed": shed, "shed_rate": shed / offered,
                         "unresolved": unresolved, "max_queue": 4,
                         "accepted_p50_s": p95 * 0.9, "accepted_p95_s": p95,
                         "p95_bound_s": bound},
            "sweep": {"network": "resnet18", "max_queue": 4,
                      "p95_bound_s": sweep_bound, "rungs": rungs},
        },
    }


def test_serving_clean_comparison_passes():
    base = _serving_payload()
    problems, _ = compare_bench.compare_serving(base, copy.deepcopy(base))
    assert problems == []


def test_serving_shed_rate_drift_beyond_band_fails():
    base = _serving_payload(shed_rate=0.8)
    cand = _serving_payload(shed_rate=0.4)  # |Δ| > 0.3 default band
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("shed_rate moved" in p for p in problems)
    # within the band: noted, not fatal
    cand = _serving_payload(shed_rate=0.65)
    problems, notes = compare_bench.compare_serving(base, cand)
    assert problems == []
    assert any("shed_rate changed" in n for n in notes)


def test_serving_zero_shed_under_overload_fails():
    """No shedding at ~2x+ offered load means the admission bound is
    silently unenforced — an unbounded queue again."""
    base = _serving_payload()
    cand = _serving_payload(shed_rate=0.0)
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("admission bound is not being enforced" in p
               for p in problems)


def test_serving_unresolved_future_fails():
    base = _serving_payload()
    cand = _serving_payload(unresolved=2)
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("never resolved" in p for p in problems)


def test_serving_p95_over_bound_fails():
    base = _serving_payload()
    cand = _serving_payload(p95=0.5, bound=0.34)
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("exceeds" in p and "bound" in p for p in problems)


def test_serving_throughput_is_noted_not_gated():
    base = _serving_payload(throughput=6.8)
    cand = _serving_payload(throughput=1.0)  # wall-clock: never gated
    problems, notes = compare_bench.compare_serving(base, cand)
    assert problems == []
    assert any("not gated" in n for n in notes)


def test_sweep_shed_below_saturation_fails():
    """A sub-capacity rung that sheds means the server rejects traffic it
    has room for — the SLO curve's left edge must be clean."""
    base = _serving_payload()
    cand = _serving_payload(sweep_rates=(0.1, 0.0, 0.2))
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("below saturation" in p for p in problems)


def test_sweep_zero_shed_above_saturation_fails():
    base = _serving_payload()
    cand = _serving_payload(sweep_rates=(0.0, 0.0, 0.0))
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("admission bound is not being enforced" in p
               for p in problems)


def test_sweep_p95_over_bound_fails_per_rung():
    base = _serving_payload()
    cand = _serving_payload(sweep_p95=(0.03, 0.25, 0.15))  # 0.5x rung blows
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("[0.5x]" in p and "bound" in p for p in problems)


def test_sweep_non_monotone_shed_fails():
    """shed(0.5x) > shed(2x) is a broken admission controller even if the
    2x rung alone looks plausible — but a clean curve must not trip it."""
    base = _serving_payload()
    # saturated rungs only: 2x sheds LESS than an imaginary earlier rung
    cand = _serving_payload()
    rungs = cand["scenarios"]["sweep"]["rungs"]
    rungs[2]["shed_rate"] = 0.3
    rungs.append({"load_factor": 4.0, "offered": 16, "accepted": 14,
                  "shed": 2, "shed_rate": 0.125, "unresolved": 0,
                  "p50_s": 0.1, "p95_s": 0.15, "p99_s": 0.16})
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("non-monotone" in p for p in problems)


def test_sweep_unresolved_and_shed_drift_fail():
    base = _serving_payload()
    cand = _serving_payload(sweep_unresolved=1)
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("never resolved" in p and "sweep" in p for p in problems)
    cand = _serving_payload(sweep_rates=(0.0, 0.0, 0.9))  # |Δ| > 0.3 band
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("shed_rate moved" in p and "[2x]" in p for p in problems)


def test_sweep_missing_rungs_fails_legacy_baseline_skips():
    base = _serving_payload()
    cand = _serving_payload()
    cand["scenarios"]["sweep"]["rungs"] = []
    problems, _ = compare_bench.compare_serving(base, cand)
    assert any("no rungs" in p for p in problems)
    # a pre-sweep baseline (no sweep scenario) never blocks a candidate
    legacy = _serving_payload()
    del legacy["scenarios"]["sweep"]
    problems, notes = compare_bench.compare_serving(
        legacy, _serving_payload())
    assert problems == []
    assert any("only in candidate" in n for n in notes)


def test_serving_kind_detection_beats_scenarios_duck_typing():
    """The serving artifact carries "scenarios" like streaming payloads;
    the explicit "kind" field must win over the structural fallback."""
    assert compare_bench._kind(_serving_payload()) == "serving"
    assert compare_bench._kind(_stream_payload()) == "streaming"
    legacy = _stream_payload()
    del legacy["kind"]  # pre-"kind" streaming artifact: duck-typed
    assert compare_bench._kind(legacy) == "streaming"


def test_serving_cli_detects_kind_and_gates(tmp_path):
    script = REPO / "tools" / "compare_bench.py"
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_serving_payload()))
    ok = subprocess.run([sys.executable, str(script), str(base), str(base)],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    assert "serving scenarios" in ok.stdout
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_serving_payload(unresolved=1)))
    r = subprocess.run([sys.executable, str(script), str(base), str(bad)],
                       capture_output=True, text=True)
    assert r.returncode == 1 and "never resolved" in r.stderr
    mixed = subprocess.run(
        [sys.executable, str(script), str(base),
         str(REPO / "benchmarks" / "baseline" / "BENCH_streaming.json")],
        capture_output=True, text=True)
    assert mixed.returncode == 1
    assert "different artifact kinds" in mixed.stderr


def test_serving_committed_baseline_vs_itself_is_clean():
    baseline = REPO / "benchmarks" / "baseline" / "BENCH_serving.json"
    d = json.loads(baseline.read_text())
    problems, _ = compare_bench.compare_serving(d, copy.deepcopy(d))
    assert problems == []
    over = d["scenarios"]["overload"]
    # the invariants the committed artifact must itself satisfy: real
    # shedding, zero unresolved futures, p95 under its own bound
    assert over["shed_rate"] > 0
    assert over["unresolved"] == 0
    assert over["accepted_p95_s"] <= over["p95_bound_s"]
    assert d["scenarios"]["steady"]["throughput_rps"] > 0
    # the sweep's own invariants: clean below saturation, shedding above,
    # monotone shed, every rung's p95 under the artifact's derived bound
    sweep = d["scenarios"]["sweep"]
    rates = []
    for rung in sweep["rungs"]:
        assert rung["unresolved"] == 0
        assert rung["p95_s"] <= sweep["p95_bound_s"]
        if rung["load_factor"] < 1.0:
            assert rung["shed_rate"] == 0
        else:
            assert rung["shed_rate"] > 0
        rates.append(rung["shed_rate"])
    assert rates == sorted(rates)


def test_cli_exit_codes(tmp_path):
    """The committed baseline vs itself exits 0; vs an injected xla
    fallback exits 1 — what the CI self-check step relies on."""
    baseline = REPO / "benchmarks" / "baseline" / "BENCH_conv.json"
    injected = tmp_path / "injected.json"
    d = json.loads(baseline.read_text())
    d["layers"][0]["algorithm"] = "xla"
    injected.write_text(json.dumps(d))
    script = REPO / "tools" / "compare_bench.py"
    ok = subprocess.run([sys.executable, str(script), str(baseline),
                         str(baseline)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    bad = subprocess.run([sys.executable, str(script), str(baseline),
                          str(injected)], capture_output=True, text=True)
    assert bad.returncode == 1
    assert "xla escape hatch" in bad.stderr
