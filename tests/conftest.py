import os
import sys
from pathlib import Path

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py forces the 512-placeholder-device topology.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
