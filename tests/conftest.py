import os
import sys
from pathlib import Path

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py forces the 512-placeholder-device topology.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def spy_algorithms(monkeypatch):
    """Wrap every registered conv kernel; record (algorithm, params).

    Shared by the plan-dispatch tests: the spy wrappers take ``**params``
    (VAR_KEYWORD), so ``ops.kernel_params`` passes dispatch's kwargs
    through untouched and the recorded params are exactly what dispatch
    was called with.
    """
    from repro.kernels import ops

    calls = []
    for name, fn in list(ops.ALGORITHMS.items()):
        def wrapper(x, w, *, impl="auto", _name=name, _fn=fn, **params):
            calls.append((_name, tuple(sorted(params.items()))))
            return _fn(x, w, impl=impl, **params)
        monkeypatch.setitem(ops.ALGORITHMS, name, wrapper)
    return calls
