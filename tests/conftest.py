import inspect
import os
import sys
from pathlib import Path

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py forces the 512-placeholder-device topology.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")


# dispatch kwargs that are call-site geometry / fused-epilogue operands,
# not tuned kernel parameters — the spies drop them so recorded calls
# compare cleanly against plan Choice.params. The block-level keys ride
# along: residual/out_act (inverted residual geometry) and res (the
# shortcut operand — a tensor, not a tunable).
NON_TUNED_KEYS = ("stride", "scale", "bias", "act", "u",
                  "residual", "res", "out_act")


def spy_algorithms(monkeypatch):
    """Wrap every registered kernel — per-conv AND block-level — and
    record (algorithm, tuned_params).

    Shared by the plan-dispatch tests: the spy wrappers take ``**params``
    (VAR_KEYWORD), so ``ops.kernel_params`` / ``ops.block_kernel_params``
    pass dispatch's kwargs through untouched; the recorded params are what
    dispatch was called with minus the non-tuned keys (stride/epilogue
    operands/the residual tensor). Block dispatches record under their
    block-algorithm names ("fused_inverted_residual" /
    "fused_residual_conv"), so e2e tests can assert a fused site produced
    exactly ONE dispatch where the per-layer plan produced two or three.
    """
    from repro.kernels import ops

    calls = []
    for table in (ops.ALGORITHMS, ops.BLOCK_ALGORITHMS):
        for name, fn in dict(table).items():
            def wrapper(x, w, *, impl="auto", _name=name, _fn=fn, **params):
                calls.append((_name, tuple(sorted(
                    (k, v) for k, v in params.items()
                    if k not in NON_TUNED_KEYS))))
                # re-apply the per-algorithm kwarg filter against the
                # *real* wrapper: the spy's **params signature disables
                # dispatch's own filtering, and the real kernels don't all
                # take every geometry key (e.g. im2col has no stride)
                accepted = inspect.signature(_fn).parameters
                return _fn(x, w, impl=impl,
                           **{k: v for k, v in params.items()
                              if k in accepted})
            monkeypatch.setitem(table, name, wrapper)
    return calls
