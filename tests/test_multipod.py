"""Multi-device tests that need a forced host-platform device count.

Run in a subprocess so the 8-device topology never leaks into the other
tests (jax locks the device count at first init — same discipline as
launch/dryrun.py).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(code: str) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n" + code)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                                         "JAX_PLATFORMS": "cpu",
                                         "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_compressed_psum_pod_matches_exact():
    """int8 EF-compressed cross-pod all-reduce ~= exact psum; error bounded
    and absorbed by the feedback state (the distributed-opt trick of
    optim/compression.py, on a real (pod, data) mesh)."""
    res = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.optim.compression import (compressed_psum_pod,
                                             init_error_state)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.arange(32.0).reshape(8, 4) / 7.0,
             "b": jnp.ones(4) * 0.3}
        err = init_error_state(g)
        with mesh:
            out, new_err = compressed_psum_pod(g, err, mesh)
        # exact cross-pod sum of identical replicas = 2x the tensor
        exact = jax.tree.map(lambda x: 2.0 * x, g)
        rel = max(float(jnp.abs(o - e).max() / (jnp.abs(e).max() + 1e-9))
                  for o, e in zip(jax.tree.leaves(out),
                                  jax.tree.leaves(exact)))
        resid = max(float(jnp.abs(v).max()) for v in jax.tree.leaves(new_err))
        print(json.dumps({"rel": rel, "resid": resid}))
    """))
    assert res["rel"] < 0.02, res      # int8: <2% after one round
    assert res["resid"] < 0.05, res    # residual captured for feedback


def test_elastic_remesh_relower():
    """Scale-down path: train step re-lowers on a smaller surviving mesh
    and the checkpointed state re-shards onto it."""
    res = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get, tiny_variant
        from repro.launch import steps
        from repro.runtime import elastic_remesh
        from repro.sharding.rules import rules_for
        from repro.models import spec as pspec
        from repro.data import TokenPipeline

        cfg = tiny_variant(get("granite-3-2b")).replace(num_layers=2)
        pipe = TokenPipeline(cfg.vocab_size, 16, 8)

        def fit_on(n_dev):
            mesh = elastic_remesh(n_dev, model_dims=[cfg.d_model, cfg.d_ff])
            rules = rules_for(cfg, mesh)
            with mesh:
                state = steps.init_state(cfg, 0)
                sh = pspec.param_shardings(steps.state_specs(cfg), mesh, rules)
                state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                     state, sh)
                ts = jax.jit(steps.make_train_step(cfg, mesh, rules))
                state, m = ts(state, pipe.batch(0, mesh=mesh, rules=rules))
                return float(m["loss"]), mesh.shape
        l8, s8 = fit_on(8)
        l4, s4 = fit_on(4)   # two devices "failed": re-mesh + re-lower
        print(json.dumps({"l8": l8, "l4": l4,
                          "s8": list(s8.values()), "s4": list(s4.values())}))
    """))
    assert abs(res["l8"] - res["l4"]) < 1e-3, res  # same math, any mesh
    assert res["s8"] != res["s4"]
