"""Fault-tolerance runtime tests: StragglerWatch breach accounting and
``resilient_train``'s restore-from-checkpoint replay path.

``TransientFailure`` raised here is the same type the serving tier's
retry policy keys on (``repro.serving.resilience`` re-exports it) — one
transient-error vocabulary across the repo, exercised from both sides.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.runtime.fault_tolerance import (
    StragglerWatch,
    TransientFailure,
    resilient_train,
)

# ----------------------------------------------------------------------
# StragglerWatch


def test_straggler_warmup_steps_are_ignored():
    """Compile-time spikes in the first ``warmup`` observations must not
    count as breaches, and no deadline exists until the post-warmup
    history reaches 5 samples."""
    w = StragglerWatch(factor=3.0, max_breaches=5, warmup=3)
    for _ in range(3):
        w.observe(10.0)  # huge "compile" steps: ignored
    for _ in range(4):
        w.observe(0.01)  # only 4 post-warmup samples: still no deadline
    assert w.breaches == 0
    w.observe(0.01)  # 5th sample arms the watch
    assert w.breaches == 0


def test_straggler_breach_accounting_and_raise():
    w = StragglerWatch(factor=3.0, max_breaches=2, warmup=0)
    for _ in range(5):
        w.observe(0.01)
    w.observe(0.02)  # 2x p50: under the 3x deadline, no breach
    assert w.breaches == 0
    w.observe(0.1)  # 10x p50: breach 1 of 2
    assert w.breaches == 1
    with pytest.raises(RuntimeError, match="straggler"):
        w.observe(0.1)  # breach 2 of 2: request the reschedule
    assert w.breaches == 2


def test_straggler_median_tracks_history():
    """The deadline follows the *running* p50, so a workload that
    legitimately slows down re-baselines instead of breaching forever."""
    w = StragglerWatch(factor=3.0, max_breaches=100, warmup=0)
    for _ in range(5):
        w.observe(0.01)
    for _ in range(20):
        w.observe(0.05)  # new steady state: 5x the old p50
    breaches_after_shift = w.breaches
    w.observe(0.06)  # near the NEW p50: must not breach
    assert w.breaches == breaches_after_shift


# ----------------------------------------------------------------------
# resilient_train replay


class _StepPipeline:
    """(seed, step)-pure data pipeline: batch(step) == step. Purity is
    what makes checkpoint replay *correct*, so the test's final state
    must equal the fault-free sum regardless of where restarts landed."""

    def batch(self, step, mesh=None, rules=None):
        return jnp.float32(step)


def _train_step(state, batch):
    w = state["w"] + batch
    return {"w": w}, {"loss": w}


def test_resilient_train_restores_from_checkpoint_and_replays(tmp_path):
    """A transient fault after a checkpoint rolls back to that checkpoint
    and replays the tail; the (seed, step)-pure pipeline makes the final
    state bit-identical to the fault-free run."""
    ckpt = CheckpointManager(tmp_path, async_save=False)
    total = 6
    fired = []

    def inject(step):
        if step == 5 and not fired:  # once, after the step-4 checkpoint
            fired.append(step)
            raise TransientFailure("injected device loss at step 5")

    state, step, failures = resilient_train(
        state={"w": jnp.float32(0.0)}, train_step=_train_step,
        pipeline=_StepPipeline(), ckpt=ckpt, total_steps=total,
        ckpt_every=2, fail_injector=inject)
    assert step == total and failures == 1
    assert float(state["w"]) == float(sum(range(total)))  # 0+1+...+5
    # the rollback really came from the step-4 checkpoint on disk
    restored_step, host_state = ckpt.restore(4)
    assert restored_step == 4
    assert float(np.asarray(host_state["w"])) == float(sum(range(4)))


def test_resilient_train_without_checkpoint_replays_from_the_top(tmp_path):
    """A fault before the first checkpoint exists has nothing to restore:
    the loop replays from ``start_step`` and still converges."""
    ckpt = CheckpointManager(tmp_path, async_save=False)
    fired = []

    def inject(step):
        if step == 1 and not fired:
            fired.append(step)
            raise TransientFailure("injected before any checkpoint")

    state, step, failures = resilient_train(
        state={"w": jnp.float32(0.0)}, train_step=_train_step,
        pipeline=_StepPipeline(), ckpt=ckpt, total_steps=3,
        ckpt_every=10, fail_injector=inject)
    assert (step, failures) == (3, 1)
    assert float(state["w"]) == float(sum(range(3)))


def test_resilient_train_gives_up_past_max_failures(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)

    def always_fail(step):
        raise TransientFailure("persistent fault")

    with pytest.raises(TransientFailure):
        resilient_train(
            state={"w": jnp.float32(0.0)}, train_step=_train_step,
            pipeline=_StepPipeline(), ckpt=ckpt, total_steps=3,
            ckpt_every=1, max_failures=2, fail_injector=always_fail)
