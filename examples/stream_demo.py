"""Streaming demo: a camera loop with a per-frame deadline.

The paper optimizes single-image latency; the canonical mobile workload
for it is a fixed-rate camera stream (openpilot's driver-monitoring loop
is the roadmap's exemplar). This demo drives that end to end:

  1. one ``Server`` (shared LRU ``EngineCache``) opens two 30 fps
     ``StreamSession``s — each holds an engine *lease*, pinning its
     engine against eviction for the session's lifetime;
  2. frames flow through the double-buffered slot: the host→device
     transfer starts at arrival and the jitted streaming forward donates
     the frame buffer;
  3. a "steady" stream (compute charge < frame period, simulated clock)
     finishes every frame on time — deadline-miss rate 0 — while an
     "overload" stream (charge > period) engages skip-to-latest and
     reports its misses;
  4. frame outputs are bitwise-equal to sequential ``engine.run`` calls —
     the demo checks this explicitly — and on-demand classify traffic
     keeps flowing through the same cache while both streams run.

    PYTHONPATH=src python examples/stream_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get, tiny_variant
from repro.serving import FrameDropped, Server, StreamScheduler

FPS = 30.0
N_FRAMES = 12


def main():
    key = jax.random.key(0)
    frames_in = [jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))
                 for i in range(N_FRAMES)]

    with Server(tiny=True, max_batch=4, window_ms=5.0) as server:
        server.warm("resnet18")
        server.warm("mobilenet_v2")
        print("== ground truth: sequential engine.run per frame ==")
        eng = server.engines.get(tiny_variant(get("resnet18")))
        truth = [np.asarray(eng.run(im)) for im in frames_in]
        print(f"  {N_FRAMES} frames, resnet18-tiny")

        print(f"\n== two {FPS:g} fps streams (simulated clock, leased "
              f"engines) ==")
        steady = server.open_stream("resnet18", fps=FPS,
                                    sim_compute_s=0.008, name="steady")
        overload = server.open_stream("resnet18", fps=FPS,
                                      sim_compute_s=0.050, name="overload")
        frames = StreamScheduler([steady, overload]).run(
            N_FRAMES, lambda i, k: frames_in[k])

        # classify traffic rides the same cache while streams are open
        classify = server.run("mobilenet_v2", frames_in[0], timeout=600)
        assert classify.shape  # on-demand path still live under streams

        print("\n== per-stream deadline accounting ==")
        for s in (steady, overload):
            st = s.stats()
            print(f"  {st['name']:9s} {st['frames']} frames: "
                  f"{st['completed']} completed, {st['dropped']} dropped, "
                  f"miss rate {st['deadline_miss_rate']:.2f} "
                  f"(deadline {st['deadline_ms']:.1f} ms)")
        assert steady.stats()["deadline_miss_rate"] == 0.0
        assert overload.stats()["dropped"] > 0  # skip-to-latest engaged

        print("\n== bitwise check vs sequential engine.run ==")
        checked = 0
        for f in frames[0]:  # the steady stream completed every frame
            assert np.array_equal(truth[f.seq],
                                  np.asarray(f.future.result(timeout=600)))
            checked += 1
        for f in frames[1]:  # overload: completed frames still bitwise
            if f.dropped:
                try:
                    f.future.result(timeout=600)
                except FrameDropped:
                    pass  # dropped frames resolve with FrameDropped
            else:
                assert np.array_equal(
                    truth[f.seq], np.asarray(f.future.result(timeout=600)))
                checked += 1
        print(f"  {checked} completed frames bitwise-equal: True")

        stats = server.stats()
        cache = stats["cache"]
        print(f"\n== cache ==\n  {cache['size']}/{cache['capacity']} "
              f"entries, {cache['misses']} builds, {cache['hits']} hits, "
              f"pinned by live leases: {len(cache['pinned'])}")


if __name__ == "__main__":
    main()
