"""Edge-deployment pipeline example (the paper's §2.3 engineering story).

Simulates the deploy workflow for a fixed CNN on a fixed device: (1) tune
once offline — the engine enumerates every conv site and the autotuner
(cost-model or measured mode) picks each site's algorithm + kernel params,
(2) freeze the per-layer plan to JSON, (3) "ship" the plan: a fresh engine
loads it without re-tuning and jits a forward with per-layer dispatch,
(4) run a stream of single images, (5) report the traffic/energy proxy.

    PYTHONPATH=src python examples/mobile_pipeline.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get, tiny_variant
from repro.core import ConvSpec, InferenceEngine, measured_select, select


def main():
    cfg = tiny_variant(get("resnet18"))

    print("== offline tuning pass (one-time, per paper §2.3) ==")
    for h, c in [(8, 64), (4, 128)]:
        spec = ConvSpec(h=h, w=h, c=c, k=c)
        cm = select(spec)
        ms = measured_select(spec, repeats=1)
        print(f"  {h}x{h} C=K={c}: cost-model -> {cm.algorithm}"
              f"{dict(cm.params)}, measured(interpret) -> {ms.algorithm}"
              f"{dict(ms.params)}")

    with tempfile.TemporaryDirectory() as td:
        plan_path = Path(td) / "plan.json"

        print("\n== freeze the per-layer plan (the shippable artifact) ==")
        tuner = InferenceEngine(cfg, seed=0)  # algorithm='auto': tunes
        tuner.save_plan(plan_path)
        algos = tuner.plan.algorithms()
        print(f"  {plan_path.name}: {len(algos)} conv sites, "
              f"algorithms {sorted(set(algos.values()))}")

        print("\n== deployed engine (loads plan, never re-tunes) ==")
        engine = InferenceEngine(cfg, params=tuner.params, plan=plan_path)
        times = []
        for i in range(5):
            img = jax.random.normal(jax.random.key(i), (32, 32, 3))
            t0 = time.perf_counter()
            engine.run(img).block_until_ready()
            times.append(time.perf_counter() - t0)
        print(f"  first call (compile): {times[0] * 1e3:.1f} ms; "
              f"steady-state: {min(times[1:]) * 1e3:.2f} ms/image")

        print("\n== traffic report (energy proxy — paper §2.2) ==")
        total = sum(r.est_bytes for r in engine.traffic_report())
        for r in engine.traffic_report():
            print(f"  {r.name:9s} {r.algorithm:8s} "
                  f"{r.est_bytes / 1e6:6.2f} MB/img")
        print(f"  total conv traffic: {total / 1e6:.2f} MB/image "
              f"(off-chip bytes ~ battery)")


if __name__ == "__main__":
    main()
