"""Edge-deployment pipeline example (the paper's §2.3 engineering story).

Simulates the deploy workflow for a fixed CNN on a fixed device: (1) tune
once offline per conv shape with the autotuner (cost-model and measured
modes), (2) freeze the per-layer algorithm plan, (3) run a stream of single
images through the jitted engine, (4) report the traffic/energy proxy.

    PYTHONPATH=src python examples/mobile_pipeline.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get, tiny_variant
from repro.core import ConvSpec, InferenceEngine, measured_select, select


def main():
    cfg = tiny_variant(get("resnet18"))

    print("== offline tuning pass (one-time, per paper §2.3) ==")
    for h, c in [(8, 64), (4, 128)]:
        spec = ConvSpec(h=h, w=h, c=c, k=c)
        cm = select(spec)
        x = jax.random.normal(jax.random.key(0), (1, h + 2, h + 2, c))
        w = jax.random.normal(jax.random.key(1), (3, 3, c, c))
        ms = measured_select(spec, x, w, repeats=1)
        print(f"  {h}x{h} C=K={c}: cost-model -> {cm.algorithm}, "
              f"measured(interpret) -> {ms.algorithm}")

    print("\n== frozen engine, image stream ==")
    engine = InferenceEngine(cfg, seed=0)
    times = []
    for i in range(5):
        img = jax.random.normal(jax.random.key(i), (32, 32, 3))
        t0 = time.perf_counter()
        engine.run(img).block_until_ready()
        times.append(time.perf_counter() - t0)
    print(f"  first call (compile): {times[0] * 1e3:.1f} ms; "
          f"steady-state: {min(times[1:]) * 1e3:.2f} ms/image")

    print("\n== traffic report (energy proxy — paper §2.2) ==")
    total = sum(r.est_bytes for r in engine.traffic_report())
    for r in engine.traffic_report():
        print(f"  {r.name}: {r.algorithm:8s} {r.est_bytes / 1e6:6.2f} MB/img")
    print(f"  total conv traffic: {total / 1e6:.2f} MB/image "
          f"(at full ResNet-18 scale; off-chip bytes ~ battery)")


if __name__ == "__main__":
    main()
