"""Serving demo: many model variants, one process, a continuous-batching
front door.

The paper's deployment scenario is single-image requests arriving one at
a time; this demo drives that end to end through the serving subsystem:

  1. one ``Server`` holds one LRU ``EngineCache`` — resnet18 and
     mobilenet_v2 (tiny variants) are tuned/jitted once each and shared —
     configured with a frozen ``ServingOptions``;
  2. a burst of concurrent single-image requests per network is coalesced
     by each network's micro-batcher into padded-batch dispatches, new
     requests joining the forming batch mid-flight (lone requests keep
     the single-image fast path); every dispatch routes through the
     shared cross-network ``DeviceScheduler``;
  3. each ``Server.submit`` returns a ``Ticket`` — the one result handle
     (``.result(timeout)``, ``.latency``);
  4. the same requests are replayed over the wire: a ``ServerEndpoint``
     socket + ``AsyncClient`` with ``await client.classify(...)``;
  5. outputs are bitwise-equal to sequential ``engine.run`` calls — in
     process AND over the socket — the demo checks this explicitly;
  6. the server's stats show the batch histogram, mid-flight joins,
     per-request latency, scheduler counters, and the cache counters.

    PYTHONPATH=src python examples/serve_demo.py
"""
import asyncio
import sys
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine
from repro.serving import AsyncClient, Server, ServerEndpoint, ServingOptions

NETWORKS = ("resnet18", "mobilenet_v2")
N_REQUESTS = 6


def main():
    key = jax.random.key(0)
    images = [jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))
              for i in range(N_REQUESTS)]

    print("== ground truth: sequential tuned-engine runs (batch 1) ==")
    engines = {net: InferenceEngine(tiny_variant(get(net)))
               for net in NETWORKS}
    truth = {net: [np.asarray(engines[net].run(im)) for im in images]
             for net in NETWORKS}
    print(f"  built {len(engines)} engines, "
          f"{N_REQUESTS} sequential runs each")

    print("\n== micro-batched server (one shared-cache process) ==")
    options = ServingOptions(max_batch=4, window_ms=100.0)
    with Server(tiny=True, options=options) as server:
        for net in NETWORKS:
            server.warm(net)  # tune/jit ahead of traffic
        tickets = {net: [] for net in NETWORKS}

        def client(net):  # one thread per network fires a request burst
            for im in images:
                tickets[net].append(server.submit(net, im))

        threads = [threading.Thread(target=client, args=(net,))
                   for net in NETWORKS]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = {net: [np.asarray(t.result(timeout=600))
                      for t in tickets[net]]
                for net in NETWORKS}

        print("\n== bitwise check vs sequential (micro-batching never "
              "changes numerics) ==")
        for net in NETWORKS:
            same = all(np.array_equal(a, b)
                       for a, b in zip(truth[net], outs[net]))
            print(f"  {net:13s} {N_REQUESTS} requests bitwise-equal: {same}")
            assert same

        print("\n== the wire: ServerEndpoint socket + AsyncClient ==")
        with ServerEndpoint(server) as endpoint:
            host, port = endpoint.address

            async def remote(net):
                async with await AsyncClient.connect(host, port) as cl:
                    return await asyncio.gather(
                        *(cl.classify(net, im) for im in images))

            for net in NETWORKS:
                wire = asyncio.run(remote(net))
                same = all(np.array_equal(a, b)
                           for a, b in zip(truth[net], wire))
                print(f"  {net:13s} {N_REQUESTS} requests over "
                      f"{host}:{port} bitwise-equal: {same}")
                assert same

        stats = server.stats()

    print("\n== server stats ==")
    cache = stats["cache"]
    print(f"  engine cache: {cache['size']}/{cache['capacity']} entries, "
          f"{cache['misses']} builds, {cache['hits']} hits, "
          f"{cache['evictions']} evictions")
    sched = stats["scheduler"]
    print(f"  device scheduler: {sched['jobs']} dispatches over "
          f"{len(sched['completed'])} networks, "
          f"queue high-water {sched['depth_high_water']}")
    for label, b in stats["networks"].items():
        lat = b["latency_mean_s"]
        print(f"  {label:20s} {b['requests']} reqs in {b['dispatches']} "
              f"dispatches, batches {b['batch_histogram']}, "
              f"{b['joined_forming']} joined mid-flight, "
              f"mean latency {lat * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
