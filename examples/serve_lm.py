"""Serving example: batched prefill + iterative decode with KV cache.

Exercises every cache type by serving three reduced archs: GQA
(granite-8b), MLA absorbed-decode (deepseek-v2), and the attention-free
recurrent path (mamba2). Verifies served greedy tokens equal teacher-forced
argmax — the correctness contract of the serving stack.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, tiny_variant
from repro.launch import steps
from repro.launch.serve import generate
from repro.models import lm


def serve_one(name, batch=4, prompt_len=16, max_new=12):
    cfg = tiny_variant(get(name)).replace(capacity_factor=8.0)
    params = steps.init_state(cfg, 0)["params"]
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompts, max_new=max_new,
                   cache_len=prompt_len + max_new)
    out.block_until_ready()
    dt = time.perf_counter() - t0

    # verify against teacher forcing
    full = jnp.concatenate([prompts, out], axis=1)
    ref_logits, _, _ = lm.forward(params, cfg, full, mode="train")
    ref = jnp.argmax(
        ref_logits[:, prompt_len - 1: prompt_len - 1 + max_new,
                   : cfg.vocab_size], -1)
    ok = bool(jnp.all(out == ref))
    print(f"{name:24s} {batch * max_new / dt:7.1f} tok/s (incl. compile)  "
          f"teacher-forcing match: {ok}")
    assert ok
    return out


def main():
    for name in ("granite-8b", "deepseek-v2-236b", "mamba2-370m"):
        serve_one(name)
    print("all serving paths verified")


if __name__ == "__main__":
    main()
