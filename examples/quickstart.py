"""Quickstart: the paper's scenario — single-image CNN inference with ILP-M.

Runs a ResNet-18 (reduced for CPU) through the tuned inference engine,
shows the per-layer tuning plan (each conv site gets its own algorithm and
kernel parameters), round-trips the plan through JSON (tune once, deploy
many), and compares all five convolution algorithms on the same image.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine, TuningPlan


def main():
    cfg = tiny_variant(get("resnet18"))
    image = jax.random.normal(jax.random.key(0), (32, 32, 3))

    print("== tuned engine (algorithm='auto': the paper's tuning library) ==")
    engine = InferenceEngine(cfg, seed=0)
    logits = engine.run(image)
    print(f"logits: shape={logits.shape}, top-3 classes:",
          jnp.argsort(logits)[-3:][::-1].tolist())

    print("\n== per-layer tuning plan (traffic report = energy proxy) ==")
    for rep in engine.traffic_report():
        params = " ".join(f"{k}={v}" for k, v in rep.params) or "-"
        print(f"  {rep.name:9s} {rep.spec.h:3d}x{rep.spec.w:<3d} "
              f"C={rep.spec.c:<3d} K={rep.spec.k:<3d}: {rep.algorithm:8s} "
              f"{params:12s} est {rep.est_time * 1e6:7.1f} us  "
              f"{rep.est_bytes / 1e6:6.2f} MB  "
              f"{rep.est_flops / 1e6:7.1f} MFLOP")

    print("\n== plan JSON round-trip (tune once, deploy many) ==")
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "resnet18_plan.json"
        engine.save_plan(path)
        deployed = InferenceEngine(cfg, params=engine.params, plan=path)
        same = bool(jnp.allclose(deployed.run(image), logits))
        print(f"  saved {path.name} ({path.stat().st_size} bytes), "
              f"reloaded plan mode={deployed.plan.mode}, "
              f"logits identical: {same}")

    print("\n== all five algorithms, same image (must agree) ==")
    ref = None
    for algo in ("xla", "ilpm", "direct", "im2col", "libdnn", "winograd"):
        eng = InferenceEngine(cfg, params=engine.params, algorithm=algo)
        out = eng.run(image)
        if ref is None:
            ref = out
        err = float(jnp.abs(out - ref).max())
        print(f"  {algo:9s} top-1={int(jnp.argmax(out))}  "
              f"max|Δ| vs xla = {err:.2e}")


if __name__ == "__main__":
    main()
