"""Quickstart: the paper's scenario — single-image CNN inference with ILP-M.

Runs a ResNet-18 (reduced for CPU) through the tuned inference engine,
compares all five convolution algorithms on the same image, and prints the
autotuner's per-stage choices + traffic report (the paper's energy proxy).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get, tiny_variant
from repro.core import InferenceEngine


def main():
    cfg = tiny_variant(get("resnet18"))
    image = jax.random.normal(jax.random.key(0), (32, 32, 3))

    print("== tuned engine (algorithm='auto': the paper's tuning library) ==")
    engine = InferenceEngine(cfg, seed=0)
    logits = engine.run(image)
    print(f"logits: shape={logits.shape}, top-3 classes:",
          jnp.argsort(logits)[-3:][::-1].tolist())

    print("\n== per-stage autotuner decisions ==")
    for rep in engine.traffic_report():
        print(f"  {rep.name}: {rep.algorithm:8s} "
              f"est {rep.est_time * 1e6:7.1f} us  "
              f"{rep.est_bytes / 1e6:6.2f} MB  "
              f"{rep.est_flops / 1e6:7.1f} MFLOP")

    print("\n== all five algorithms, same image (must agree) ==")
    ref = None
    for algo in ("xla", "ilpm", "direct", "im2col", "libdnn", "winograd"):
        eng = InferenceEngine(cfg, params=engine.params, algorithm=algo)
        out = eng.run(image)
        if ref is None:
            ref = out
        err = float(jnp.abs(out - ref).max())
        print(f"  {algo:9s} top-1={int(jnp.argmax(out))}  "
              f"max|Δ| vs xla = {err:.2e}")


if __name__ == "__main__":
    main()
