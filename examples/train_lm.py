"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses qwen2-0.5b's family at reduced width (≈100M params at vocab 8k) with
the full production stack: sharded state, AdamW + warmup-cosine, global-norm
clipping, deterministic data pipeline, async checkpointing, and the
resilient train loop (a fault is INJECTED mid-run to demonstrate
checkpoint/restart — the run still finishes and the loss keeps falling).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import TokenPipeline
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.runtime import TransientFailure, resilient_train
from repro.sharding.rules import rules_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: qwen2 family, 8 layers x d_model 640, vocab 8192
    cfg = get("qwen2-0.5b").replace(
        name="qwen2-100m", num_layers=8, d_model=640, num_heads=10,
        num_kv_heads=2, head_dim=64, d_ff=1792, vocab_size=8192,
        dtype="float32", param_dtype="float32", remat="none", attn_chunk=128)
    n = cfg.num_params()
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M")

    mesh = make_local_mesh()
    rules = rules_for(cfg, mesh)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)

    losses = []

    def on_metrics(step, m, dt):
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  {dt * 1e3:.0f} ms",
                  flush=True)

    injected = {args.steps // 2: True}

    def chaos(step):
        if injected.pop(step, None):
            print(f"*** injecting node failure at step {step} "
                  f"(checkpoint/restart will recover) ***")
            raise TransientFailure("injected")

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = CheckpointManager(tmp, keep=2)
        with mesh:
            train_step = jax.jit(steps.make_train_step(
                cfg, mesh, rules, peak_lr=3e-4, warmup=min(30, args.steps // 4),
                total_steps=args.steps))
            state = steps.init_state(cfg, 0)
            state, step, fails = resilient_train(
                state=state, train_step=train_step, pipeline=pipe,
                ckpt=ckpt, total_steps=args.steps,
                ckpt_every=max(10, args.steps // 6),
                fail_injector=chaos, mesh=mesh, rules=rules,
                on_metrics=on_metrics)

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nfinished: {step} steps, {fails} restart(s), "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
