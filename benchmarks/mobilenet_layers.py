"""MobileNetV2 per-layer cost-model benchmark — the grouped-family analogue
of the paper's Fig. 5 grid.

For every conv site of MobileNetV2 at 224x224 (stem, each block's expand /
depthwise / project, head) we report the tuned choice and the roofline time
on each device's constants, plus the depthwise-vs-XLA and layer-mix
aggregates that motivate the grouped kernels: Zhang et al. (2020) observe
depthwise + pointwise layers dominate MobileNet inference time, and the
per-layer split below reproduces that — pointwise GEMMs carry the FLOPs
while depthwise layers are pure-bandwidth and live or die on residency.

    PYTHONPATH=src:. python benchmarks/mobilenet_layers.py
"""
from __future__ import annotations

from benchmarks.devices import DEVICES
from repro.configs import get
from repro.core.autotune import cost_model_select, xla_choice
from repro.models import mobilenet


def run():
    cfg = get("mobilenet_v2")
    sites = mobilenet.conv_specs(cfg)
    rows = []
    for dev, (peak, bw) in DEVICES.items():
        for name, spec in sites:
            tuned = cost_model_select(spec, peak_flops=peak, hbm_bw=bw)
            xla = xla_choice(spec, peak_flops=peak, hbm_bw=bw)
            kind = ("depthwise" if spec.depthwise
                    else "pointwise" if spec.r == 1 else "dense")
            rows.append({
                "device": dev, "layer": name, "kind": kind,
                "hw": f"{spec.h}x{spec.w}", "c": spec.c, "k": spec.k,
                "stride": spec.stride,
                "tuned": tuned.algorithm + "".join(
                    f":{k}={v}" for k, v in tuned.params),
                "t_us": round(tuned.est_time * 1e6, 2),
                "t_xla_us": round(xla.est_time * 1e6, 2),
                "flops": tuned.est_flops, "bytes": tuned.est_bytes,
            })
    return rows


def headline(rows):
    """Per-device layer-mix totals (the Zhang et al. observation)."""
    out = {}
    for dev in DEVICES:
        mine = [r for r in rows if r["device"] == dev]
        by_kind = {}
        for kind in ("depthwise", "pointwise", "dense"):
            by_kind[kind] = round(sum(r["t_us"] for r in mine
                                      if r["kind"] == kind), 1)
        total = sum(by_kind.values())
        out[dev] = {"total_us": round(total, 1),
                    **{f"{k}_share": round(v / total, 3)
                       for k, v in by_kind.items()}}
    return out


def main():
    rows = run()
    cols = ["device", "layer", "kind", "hw", "c", "k", "stride", "tuned",
            "t_us", "t_xla_us"]
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    print("# layer-mix:", headline(rows))


if __name__ == "__main__":
    main()
