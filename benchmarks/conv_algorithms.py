"""Paper Fig. 5 analogue: per-layer algorithm comparison across devices.

The paper measures wall time for 5 algorithms x 4 ResNet layer shapes x 3
GPUs. Off-hardware we evaluate the same grid with the two-term roofline
cost model (FLOPs/peak vs bytes/bandwidth, per-algorithm traffic from the
autotuner's candidate generator) on the paper's device constants + TPU v5e,
and report the speedup ratios the paper headlines.

Expected qualitative reproduction (paper §5.1):
  * bandwidth-limited devices (Mali, Vega): ILP-M fastest everywhere;
  * high-bandwidth device (Radeon VII / v5e): Winograd competitive;
  * libdnn beats im2col on low-bandwidth, loses on high-bandwidth.
"""
from __future__ import annotations

from benchmarks.devices import DEVICES
from repro.configs.resnet import PAPER_CONV_LAYERS
from repro.core.autotune import _candidates, build_plan, cost_model_select
from repro.core.convspec import ConvSpec

# instruction-overhead multipliers on the compute term, from the paper's
# Table 4 instruction profile (vector+scalar instructions normalized to
# useful MACs; see EXPERIMENTS.md §Paper-repro for the derivation)
INSTR_OVERHEAD = {
    "im2col": 1.38, "libdnn": 1.90, "winograd": 1.00, "direct": 1.68,
    "ilpm": 1.00,
}


def best_time(spec: ConvSpec, algo: str, peak, bw, el=4):
    """Min over the algorithm's tile candidates of the roofline time."""
    best = None
    for a, params, bts, flops, vmem in _candidates(spec):
        if a != algo:
            continue
        t = max(flops * INSTR_OVERHEAD[a] / peak, bts / bw)
        best = t if best is None else min(best, t)
    return best


def run():
    rows = []
    for dev, (peak, bw) in DEVICES.items():
        for layer in PAPER_CONV_LAYERS:
            spec = ConvSpec(h=layer.h, w=layer.w, c=layer.c_in, k=layer.c_out)
            times = {}
            for algo in ("im2col", "libdnn", "winograd", "direct", "ilpm"):
                t = best_time(spec, algo, peak, bw)
                if t is not None:
                    times[algo] = t
            row = {"device": dev, "layer": layer.name}
            row.update({a: round(t * 1e6, 2) for a, t in times.items()})
            # what the shipping autotuner (no instruction-overhead term)
            # would put in this device's TuningPlan for this layer
            tuned = cost_model_select(spec, peak_flops=peak, hbm_bw=bw)
            row["tuned"] = tuned.algorithm + "".join(
                f":{k}={v}" for k, v in tuned.params)
            row["ilpm_vs_im2col"] = round(times["im2col"] / times["ilpm"], 2)
            row["ilpm_vs_direct"] = round(times["direct"] / times["ilpm"], 2)
            if "winograd" in times:
                row["ilpm_vs_winograd"] = round(
                    times["winograd"] / times["ilpm"], 2)
            rows.append(row)
    return rows


def headline(rows):
    """Paper claims: 14.6x vs im2col, 2.30x vs direct (mobile GPU)."""
    mali = [r for r in rows if r["device"] == "mali_g76"]
    return {
        "mali_ilpm_vs_im2col_range": (min(r["ilpm_vs_im2col"] for r in mali),
                                      max(r["ilpm_vs_im2col"] for r in mali)),
        "mali_ilpm_vs_direct_range": (min(r["ilpm_vs_direct"] for r in mali),
                                      max(r["ilpm_vs_direct"] for r in mali)),
        "paper_claims": {"vs_im2col": 14.6, "vs_direct": 2.30},
    }


def main():
    rows = run()
    cols = ["device", "layer", "im2col", "libdnn", "winograd", "direct",
            "ilpm", "tuned", "ilpm_vs_im2col", "ilpm_vs_direct"]
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    print("#", headline(rows))
    # the v5e plan the engine would ship for the paper's four layer shapes
    plan = build_plan(
        (layer.name,
         ConvSpec(h=layer.h, w=layer.w, c=layer.c_in, k=layer.c_out))
        for layer in PAPER_CONV_LAYERS)
    print("# v5e plan:", {n: c.algorithm + str(dict(c.params))
                          for n, c in plan.choices.items()})


if __name__ == "__main__":
    main()
