"""Benchmark driver — one section per paper table/figure + the roofline.

  conv_memory      — paper Table 3 (memory traffic) reproduction
  conv_algorithms  — paper Fig. 5 (exec time across devices) cost-model
  conv_arith       — paper Table 4 (arithmetic profile) + interpret wall
  autotune         — the paper's tuning library on every ResNet layer
  roofline         — §Roofline table from the multi-pod dry-run artifacts

``--json PATH`` switches to the machine-readable emitter instead: it tunes
the tiny config end-to-end and writes one record per conv site (algorithm,
tuned params, cost-model estimates, ConvSpec flops/bytes, and an
interpret-mode proxy timing of the chosen kernel) so CI can track the perf
trajectory across PRs. ``--config`` picks the network (default resnet18).

``--serve PATH`` exercises the serving subsystem instead: concurrent
single-image requests for >= 2 networks through one micro-batching
``Server`` (one shared EngineCache process), reporting per-network
throughput, latency percentiles, and batch-size histograms to
BENCH_serving.json. CPU interpret-mode numbers are a trend line across
PRs, not absolute device performance.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def _proxy_time(spec, choice, repeats=2):
    """Interpret-mode wall-clock of the site's chosen kernel (min of
    ``repeats`` after a warm-up) — a CPU proxy, not TPU time; useful as a
    trend line across PRs, not as an absolute number."""
    from repro.core.autotune import _synth_inputs
    from repro.kernels import ops, ref

    try:
        x, w = _synth_inputs(spec)
        if choice.algorithm == "xla":
            def run():
                return ref.conv2d_reference(x, w, stride=spec.stride,
                                            padding="VALID",
                                            groups=spec.groups)
        else:
            def run():
                return ops.dispatch(choice.algorithm, x, w, impl="pallas",
                                    stride=spec.stride,
                                    **dict(choice.params))
        run().block_until_ready()  # warm-up / compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run().block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)
    except Exception as e:  # pragma: no cover - robustness for CI smoke
        print(f"  proxy timing failed for {choice.algorithm} on {spec}: {e}",
              file=sys.stderr)
        return None


def emit_json(path, config="resnet18"):
    """Tune the tiny variant of ``config`` and dump the per-layer plan +
    proxy timings to ``path`` (the BENCH_conv.json CI artifact)."""
    from dataclasses import asdict

    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine

    cfg = tiny_variant(get(config))
    eng = InferenceEngine(cfg)
    plan = eng.plan
    layers = []
    for name, spec in plan.specs.items():
        ch = plan.choices[name]
        layers.append({
            "layer": name,
            "algorithm": ch.algorithm,
            "params": dict(ch.params),
            "est_time_s": ch.est_time,
            "est_bytes": ch.est_bytes,
            "est_flops": ch.est_flops,
            "vmem_bytes": ch.vmem,
            "flops": spec.flops,
            "bytes_min": spec.bytes_min,
            "interpret_time_s": _proxy_time(spec, ch),
            "spec": asdict(spec),
        })
    timed = [l["interpret_time_s"] for l in layers
             if l["interpret_time_s"] is not None]
    payload = {
        "config": cfg.name,
        "mode": plan.mode,
        "n_sites": len(layers),
        "algorithms": sorted({l["algorithm"] for l in layers}),
        "xla_sites": [l["layer"] for l in layers if l["algorithm"] == "xla"],
        "totals": {
            "est_time_s": sum(l["est_time_s"] for l in layers),
            "est_bytes": sum(l["est_bytes"] for l in layers),
            "flops": sum(l["flops"] for l in layers),
            "interpret_time_s": sum(timed),
        },
        "layers": layers,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}: {payload['n_sites']} sites "
          f"({', '.join(payload['algorithms'])}), "
          f"{len(payload['xla_sites'])} xla fallbacks")


def emit_serving_json(path, networks=("resnet18", "mobilenet_v2"),
                      requests_per_net=12, max_batch=4, window_ms=20.0):
    """Serve ``requests_per_net`` single-image requests per network through
    one micro-batching Server (shared EngineCache) and dump per-network
    throughput/latency + cache stats to ``path`` (BENCH_serving.json)."""
    import jax

    from repro.serving import Server

    assert len(networks) >= 2, "serving bench covers >= 2 networks"
    server = Server(tiny=True, max_batch=max_batch, window_ms=window_ms)
    key = jax.random.key(0)
    img = jax.random.normal(key, (32, 32, 3))
    for net in networks:  # build + jit outside the timed window
        server.run(net, img)
    t0 = time.perf_counter()
    futures = []
    for i in range(requests_per_net):  # interleave networks: the shared
        for net in networks:           # cache serves them side by side
            futures.append(server.submit(
                net, jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))))
    for f in futures:
        f.result(timeout=600)
    wall = time.perf_counter() - t0
    stats = server.stats()
    server.close()
    payload = {
        "networks": list(networks),
        "requests_per_net": requests_per_net,
        "max_batch": max_batch,
        "window_ms": window_ms,
        "wall_s": wall,
        "throughput_rps": len(futures) / wall,
        "per_network": stats["networks"],
        "cache": stats["cache"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}: {len(futures)} requests over {len(networks)} "
          f"networks in {wall:.2f}s ({payload['throughput_rps']:.1f} req/s), "
          f"cache {payload['cache']['misses']} builds / "
          f"{payload['cache']['hits']} hits")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="emit the per-layer plan + proxy timings as JSON "
                         "and exit (CI smoke mode)")
    ap.add_argument("--config", default="resnet18",
                    help="network for --json (tiny variant is used)")
    ap.add_argument("--serve", metavar="PATH",
                    help="run the micro-batched serving bench and emit "
                         "throughput/latency JSON (BENCH_serving.json)")
    args = ap.parse_args(argv)
    if args.json:
        emit_json(args.json, config=args.config)
        return
    if args.serve:
        emit_serving_json(args.serve)
        return

    t0 = time.time()
    from benchmarks import conv_algorithms, conv_arith, conv_memory, roofline

    _section("paper Table 3: global-memory traffic (analytic vs measured)")
    conv_memory.main()

    _section("paper Fig. 5: algorithm x layer x device (roofline cost model)")
    conv_algorithms.main()

    _section("paper Table 4: arithmetic profile + kernel wall (interpret)")
    conv_arith.main()

    _section("autotuner choices per ResNet layer (paper's tuning library)")
    from repro.core import ConvSpec, select
    from repro.configs.resnet import PAPER_CONV_LAYERS

    print("layer,algorithm,est_us_v5e,est_bytes_MB,vmem_MB")
    for layer in PAPER_CONV_LAYERS:
        ch = select(ConvSpec(h=layer.h, w=layer.w, c=layer.c_in, k=layer.c_out))
        print(f"{layer.name},{ch.algorithm},{ch.est_time * 1e6:.2f},"
              f"{ch.est_bytes / 1e6:.2f},{ch.vmem / 2 ** 20:.2f}")

    _section("roofline (from dry-run artifacts)")
    roofline.main()

    print(f"\n# benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
