"""Benchmark driver — one section per paper table/figure + the roofline.

  conv_memory      — paper Table 3 (memory traffic) reproduction
  conv_algorithms  — paper Fig. 5 (exec time across devices) cost-model
  conv_arith       — paper Table 4 (arithmetic profile) + interpret wall
  autotune         — the paper's tuning library on every ResNet layer
  roofline         — §Roofline table from the multi-pod dry-run artifacts
"""
from __future__ import annotations

import sys
import time


def _section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def main() -> None:
    t0 = time.time()
    from benchmarks import conv_algorithms, conv_arith, conv_memory, roofline

    _section("paper Table 3: global-memory traffic (analytic vs measured)")
    conv_memory.main()

    _section("paper Fig. 5: algorithm x layer x device (roofline cost model)")
    conv_algorithms.main()

    _section("paper Table 4: arithmetic profile + kernel wall (interpret)")
    conv_arith.main()

    _section("autotuner choices per ResNet layer (paper's tuning library)")
    from repro.core import ConvSpec, select
    from repro.configs.resnet import PAPER_CONV_LAYERS

    print("layer,algorithm,est_us_v5e,est_bytes_MB,vmem_MB")
    for layer in PAPER_CONV_LAYERS:
        ch = select(ConvSpec(h=layer.h, w=layer.w, c=layer.c_in, k=layer.c_out))
        print(f"{layer.name},{ch.algorithm},{ch.est_time * 1e6:.2f},"
              f"{ch.est_bytes / 1e6:.2f},{ch.vmem / 2 ** 20:.2f}")

    _section("roofline (from dry-run artifacts)")
    roofline.main()

    print(f"\n# benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
