"""Benchmark driver — one section per paper table/figure + the roofline.

  conv_memory      — paper Table 3 (memory traffic) reproduction
  conv_algorithms  — paper Fig. 5 (exec time across devices) cost-model
  conv_arith       — paper Table 4 (arithmetic profile) + interpret wall
  autotune         — the paper's tuning library on every ResNet layer
  roofline         — §Roofline table from the multi-pod dry-run artifacts

``--json PATH`` switches to the machine-readable emitter instead: it tunes
the tiny config end-to-end and writes one record per conv site (algorithm,
tuned params, cost-model estimates, ConvSpec flops/bytes, and an
interpret-mode proxy timing of the chosen kernel) so CI can track the perf
trajectory across PRs. ``--config`` picks the network (default resnet18).

``--serve PATH`` exercises the serving subsystem instead: concurrent
single-image requests for >= 2 networks through one micro-batching
``Server`` (one shared EngineCache process), reporting per-network
throughput, latency percentiles, and batch-size histograms to
BENCH_serving.json. CPU interpret-mode numbers are a trend line across
PRs, not absolute device performance.

``--stream PATH`` runs the streaming scenario: K concurrent 30 fps
simulated-clock streams (per-stream engine leases out of one shared
cache) alongside on-demand classify traffic, reporting per-stream
deadline-miss rate, drop rate, frame latency percentiles, and classify
contention to BENCH_streaming.json. The simulated-clock numbers are
deterministic, so CI gates on the miss rate (tools/compare_bench.py);
the wall-clock classify/contention numbers are an ungated trend line.

``--quant PATH`` runs the accuracy-vs-speed precision sweep: one fp32
reference engine, then bf16 / fp16 / int8-weight variants sharing the
same parameter values, each classifying the same image set. Per-precision
rows (top-1 agreement with fp32, max relative logit error, dtype-keyed
cost-model totals, weight storage bytes, xla fallback sites) go to
BENCH_quant.json; tools/compare_bench.py gates agreement drops and any
tuned-site -> xla fallback in low precision against the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def _proxy_time(spec, choice, repeats=2):
    """Interpret-mode wall-clock of the site's chosen kernel (min of
    ``repeats`` after a warm-up) — a CPU proxy, not TPU time; useful as a
    trend line across PRs, not as an absolute number."""
    from repro.core.autotune import _synth_inputs
    from repro.kernels import ops, ref

    try:
        x, w = _synth_inputs(spec)
        if choice.algorithm == "xla":
            def run():
                return ref.conv2d_reference(x, w, stride=spec.stride,
                                            padding="VALID",
                                            groups=spec.groups)
        else:
            def run():
                return ops.dispatch(choice.algorithm, x, w, impl="pallas",
                                    stride=spec.stride,
                                    **dict(choice.params))
        run().block_until_ready()  # warm-up / compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run().block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)
    except Exception as e:  # pragma: no cover - robustness for CI smoke
        print(f"  proxy timing failed for {choice.algorithm} on {spec}: {e}",
              file=sys.stderr)
        return None


def emit_json(path, config="resnet18"):
    """Tune the tiny variant of ``config`` and dump the per-layer plan +
    proxy timings to ``path`` (the BENCH_conv.json CI artifact).

    Besides the per-conv ``layers`` rows, the artifact carries one
    ``blocks`` row per fusible block site — fused or not — comparing the
    fused megakernel's cost-model estimates against the per-layer
    constituent sum (plus the unfused shortcut-add pass where the block
    carries a residual). ``tools/compare_bench.py`` gates on these rows: a
    previously-fused site regressing to per-layer fails CI, and every
    fused row's byte estimate must sit below its per-layer sum.
    """
    from dataclasses import asdict

    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine, autotune

    cfg = tiny_variant(get(config))
    eng = InferenceEngine(cfg)
    plan = eng.plan
    layers = []
    for name, spec in plan.specs.items():
        ch = plan.choices[name]
        layers.append({
            "layer": name,
            "algorithm": ch.algorithm,
            "params": dict(ch.params),
            "est_time_s": ch.est_time,
            "est_bytes": ch.est_bytes,
            "est_flops": ch.est_flops,
            "vmem_bytes": ch.vmem,
            "flops": spec.flops,
            "bytes_min": spec.bytes_min,
            "interpret_time_s": _proxy_time(spec, ch),
            "spec": asdict(spec),
        })
    timed = [l["interpret_time_s"] for l in layers
             if l["interpret_time_s"] is not None]
    blocks = []
    for name, bspec in eng._block_specs():
        ch = plan.block_choices.get(name)
        per_layer_bytes = sum(
            c.est_bytes for c in autotune.block_constituents(
                bspec, epilogue=True)) + bspec.residual_pass_bytes
        blocks.append({
            "block": name,
            "kind": bspec.kind,
            "fused": ch is not None,
            "algorithm": ch.algorithm if ch else None,
            "params": dict(ch.params) if ch else {},
            "est_time_s": ch.est_time if ch else None,
            "est_bytes": ch.est_bytes if ch else None,
            "vmem_bytes": ch.vmem if ch else None,
            "per_layer_est_time_s": autotune.block_baseline_time(
                bspec, epilogue=True),
            "per_layer_est_bytes": per_layer_bytes,
            "saved_bytes": bspec.saved_bytes,
            "spec": asdict(bspec),
        })
    payload = {
        "config": cfg.name,
        "mode": plan.mode,
        "n_sites": len(layers),
        "algorithms": sorted({l["algorithm"] for l in layers}),
        "xla_sites": [l["layer"] for l in layers if l["algorithm"] == "xla"],
        "fused_sites": [b["block"] for b in blocks if b["fused"]],
        "totals": {
            "est_time_s": sum(l["est_time_s"] for l in layers),
            "est_bytes": sum(l["est_bytes"] for l in layers),
            "flops": sum(l["flops"] for l in layers),
            "interpret_time_s": sum(timed),
        },
        "layers": layers,
        "blocks": blocks,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}: {payload['n_sites']} sites "
          f"({', '.join(payload['algorithms'])}), "
          f"{len(payload['xla_sites'])} xla fallbacks, "
          f"{len(payload['fused_sites'])}/{len(blocks)} block sites fused")


def _serving_steady(networks, requests_per_net, max_batch, window_ms):
    """The throughput leg: interleaved single-image requests over >= 2
    networks through one shared-cache Server; every request must resolve."""
    import jax

    from repro.serving import Server, ServingOptions

    server = Server(tiny=True, options=ServingOptions(
        max_batch=max_batch, window_ms=window_ms))
    key = jax.random.key(0)
    img = jax.random.normal(key, (32, 32, 3))
    for net in networks:  # build + jit outside the timed window
        server.run(net, img)
    t0 = time.perf_counter()
    tickets = []
    for i in range(requests_per_net):  # interleave networks: the shared
        for net in networks:           # cache serves them side by side
            tickets.append(server.submit(
                net, jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))))
    for t in tickets:
        t.result(timeout=600)
    wall = time.perf_counter() - t0
    stats = server.stats()
    server.close()
    return {
        "requests": len(tickets),
        "requests_per_net": requests_per_net,
        "wall_s": wall,
        "throughput_rps": len(tickets) / wall,
        "per_network": stats["networks"],
        "cache": stats["cache"],
    }


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _serving_overload(network, *, offered=80, max_queue=4,
                      service_delay_s=0.04, submit_interval_s=0.005):
    """The overload leg: offer ~2x+ the server's capacity and prove the
    admission controller sheds the excess instead of queueing it.

    A ``FaultInjector`` latency fault pins the per-dispatch service time
    to a known floor (``service_delay_s``), so the offered load exceeds
    capacity regardless of machine speed; submissions arrive every
    ``submit_interval_s`` — well past capacity — against a ``max_queue``
    admission bound. The gate (tools/compare_bench.py, serving kind)
    holds: shed_rate within a band of the baseline AND nonzero, every
    accepted Future resolved (``unresolved == 0``), and accepted-request
    p95 latency under ``p95_bound_s`` — bounded queueing is the point:
    admission control converts unbounded latency into typed rejections.

    ``p95_bound_s`` is derived on the spot from the machine's measured
    warm service time: an admitted request waits for at most ``max_queue``
    requests ahead of it, so p95 must sit under ``(max_queue + 3) *
    service_s`` (one slot of in-flight slack, two of timer slop). The
    bound travels in the artifact, making the gate self-contained and
    machine-portable — what it pins is the *queue-depth invariant*, not an
    absolute wall-clock number.
    """
    import jax

    from repro.serving import FaultInjector, Overloaded, Server, ServingOptions

    faults = FaultInjector().delay_from("dispatch", 0,
                                        seconds=service_delay_s)
    server = Server(tiny=True, options=ServingOptions(
        max_batch=1, window_ms=0.5, max_queue=max_queue, faults=faults))
    key = jax.random.key(1)
    img = jax.random.normal(key, (32, 32, 3))
    server.warm(network)  # build outside the overloaded window
    # measure the warm per-request service time (injected floor + engine
    # compute on THIS machine); min of a few trials rejects scheduler noise
    service_s = min(
        _timed(lambda: server.run(network, img)) for _ in range(3))
    p95_bound_s = (max_queue + 3) * service_s
    tickets, shed = [], 0
    t0 = time.perf_counter()
    for i in range(offered):
        try:
            tickets.append(server.submit(network, img))
        except Overloaded:
            shed += 1
        time.sleep(submit_interval_s)
    unresolved = 0
    for t in tickets:
        try:
            t.result(timeout=600)
        except Exception:
            unresolved += 1  # an accepted request MUST resolve
    wall = time.perf_counter() - t0
    per_net = server.stats()["networks"]
    server.close()
    accepted = len(tickets)
    b = next(iter(per_net.values()))  # single-network scenario
    return {
        "offered": offered,
        "accepted": accepted,
        "shed": shed,
        "shed_rate": shed / offered,
        "unresolved": unresolved,
        "max_queue": max_queue,
        "service_delay_s": service_delay_s,
        "measured_service_s": service_s,
        "submit_interval_s": submit_interval_s,
        "wall_s": wall,
        "accepted_p50_s": b["latency_p50_s"],
        "accepted_p95_s": b["latency_p95_s"],
        "p95_bound_s": p95_bound_s,
        "shed_by_cause": b["shed"],
        "retries": b["retries"],
        "breaker": b["breaker"],
    }


def _serving_sweep(network, *, load_factors=(0.25, 0.5, 2.0),
                   n_requests=16, max_queue=4, service_delay_s=0.025):
    """The SLO-curve leg: an offered-QPS ladder against one server,
    per-rung p50/p95/p99 + shed rate — so the bench gate holds a latency
    curve, not one overload point.

    Like the overload leg, a ``FaultInjector`` latency fault pins the
    per-dispatch service time to a known floor, and capacity is
    *measured* on the spot (``capacity_qps = 1 / warm service time``), so
    the rungs are machine-portable: each rung offers
    ``load_factor * capacity_qps``, arrivals paced open-loop. The
    invariants the gate holds per rung:

      * **below saturation** (load_factor < 1): ``shed_rate == 0`` and
        p95 under the derived ``p95_bound_s`` — an unloaded server must
        not reject or queue;
      * **above saturation**: shedding engages (rate > 0) while accepted
        p95 stays bounded — the overload trade, now anchored to a curve;
      * **monotone shed** — shed_rate must not decrease as offered load
        rises: a non-monotone curve means admission control is load-
        dependent in the wrong direction;
      * every accepted request resolves (``unresolved == 0``), at every
        rung.
    """
    import jax

    from repro.serving import FaultInjector, Rejected, Server, ServingOptions

    faults = FaultInjector().delay_from("dispatch", 0,
                                        seconds=service_delay_s)
    server = Server(tiny=True, options=ServingOptions(
        max_batch=1, window_ms=0.5, max_queue=max_queue, faults=faults))
    key = jax.random.key(2)
    img = jax.random.normal(key, (32, 32, 3))
    server.warm(network)  # build + jit outside every timed rung
    service_s = min(
        _timed(lambda: server.run(network, img)) for _ in range(3))
    capacity_qps = 1.0 / service_s
    p95_bound_s = (max_queue + 3) * service_s
    rungs = []
    for lf in load_factors:
        offered_qps = lf * capacity_qps
        interval = 1.0 / offered_qps
        tickets, shed = [], 0
        t0 = time.perf_counter()
        for _ in range(n_requests):
            try:
                tickets.append(server.submit(network, img))
            except Rejected:
                shed += 1
            time.sleep(interval)
        lats, unresolved = [], 0
        for t in tickets:
            try:
                t.result(timeout=600)
                lats.append(t.latency)
            except Exception:
                unresolved += 1
        wall = time.perf_counter() - t0
        lats.sort()

        def pct(q):
            if not lats:
                return None
            return lats[min(len(lats) - 1,
                            round(q / 100 * (len(lats) - 1)))]

        rungs.append({
            "load_factor": lf,
            "offered_qps": offered_qps,
            "offered": n_requests,
            "accepted": len(tickets),
            "shed": shed,
            "shed_rate": shed / n_requests,
            "unresolved": unresolved,
            "p50_s": pct(50),
            "p95_s": pct(95),
            "p99_s": pct(99),
            "wall_s": wall,
        })
    stats = server.stats()
    server.close()
    return {
        "network": network,
        "n_requests": n_requests,
        "max_queue": max_queue,
        "service_delay_s": service_delay_s,
        "measured_service_s": service_s,
        "capacity_qps": capacity_qps,
        "p95_bound_s": p95_bound_s,
        "scheduler": stats["scheduler"],
        "rungs": rungs,
    }


def emit_serving_json(path, networks=("resnet18", "mobilenet_v2"),
                      requests_per_net=12, max_batch=4, window_ms=20.0):
    """Run the serving scenarios and dump BENCH_serving.json.

    Three scenarios: **steady** — interleaved single-image requests per
    network through one micro-batching Server (shared EngineCache),
    per-network throughput/latency + cache stats; **overload** — ~2x+
    capacity offered against a bounded queue, proving admission control
    sheds with typed ``Overloaded`` while accepted requests keep bounded
    latency; **sweep** — an offered-QPS ladder (fractions and multiples
    of measured capacity) recording p50/p95/p99 + shed rate per rung, so
    the gate holds the whole SLO curve. The CI gate
    (tools/compare_bench.py) holds the overload and sweep invariants
    against the committed baseline.
    """
    assert len(networks) >= 2, "serving bench covers >= 2 networks"
    steady = _serving_steady(networks, requests_per_net, max_batch,
                             window_ms)
    overload = _serving_overload(networks[0])
    sweep = _serving_sweep(networks[0])
    payload = {
        "kind": "serving",
        "networks": list(networks),
        "max_batch": max_batch,
        "window_ms": window_ms,
        "scenarios": {"steady": steady, "overload": overload,
                      "sweep": sweep},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    rung_summary = ", ".join(
        f"{r['load_factor']:g}x: p95 {r['p95_s']:.3f}s shed "
        f"{r['shed_rate']:.2f}" for r in sweep["rungs"])
    print(f"wrote {path}: steady {steady['requests']} requests over "
          f"{len(networks)} networks in {steady['wall_s']:.2f}s "
          f"({steady['throughput_rps']:.1f} req/s, cache "
          f"{steady['cache']['misses']} builds / {steady['cache']['hits']} "
          f"hits); overload shed {overload['shed']}/{overload['offered']} "
          f"(rate {overload['shed_rate']:.2f}), accepted p95 "
          f"{overload['accepted_p95_s']:.3f}s <= {overload['p95_bound_s']}s "
          f"bound, {overload['unresolved']} unresolved; sweep "
          f"[{rung_summary}]")


def emit_streaming_json(path, *, networks=("resnet18", "mobilenet_v2"),
                        n_streams=4, fps=30.0, frames_per_stream=45,
                        classify_requests=8,
                        scenarios=(("steady", 0.008), ("overload", 0.050))):
    """Run the multi-stream deadline scenario and dump BENCH_streaming.json.

    Each scenario opens ``n_streams`` simulated-clock 30 fps sessions
    (round-robin over ``networks``, phase-staggered) on one shared-cache
    ``Server`` while a classify client pushes on-demand ``Server.submit``
    traffic through the same cache. The per-frame sim compute charge is
    the scenario knob: "steady" (charge < frame period) must hold a zero
    deadline-miss rate; "overload" (charge > period) must engage
    skip-to-latest and report the misses. Sim-time aggregates are
    deterministic — the CI gate compares them against the committed
    baseline — while classify latencies are wall-clock trend lines.
    """
    import threading

    import jax

    from repro.serving import Server, ServingOptions, StreamScheduler

    key = jax.random.key(0)
    imgs = [jax.random.normal(jax.random.fold_in(key, i), (32, 32, 3))
            for i in range(frames_per_stream)]
    period = 1.0 / fps
    out_scenarios = {}
    t_start = time.perf_counter()
    for name, charge_s in scenarios:
        server = Server(tiny=True, options=ServingOptions(
            max_batch=4, window_ms=5.0))
        for net in networks:  # build + jit outside the measured window
            server.run(net, imgs[0])
        streams = [server.open_stream(networks[i % len(networks)], fps=fps,
                                      sim_compute_s=charge_s,
                                      phase_s=i * period / n_streams,
                                      name=f"{name}-{i}")
                   for i in range(n_streams)]
        classify_lat = []

        def classify_client():
            for i in range(classify_requests):
                net = networks[i % len(networks)]
                t0 = time.perf_counter()
                server.run(net, imgs[i % len(imgs)], timeout=600)
                classify_lat.append(time.perf_counter() - t0)

        client = threading.Thread(target=classify_client)
        client.start()
        t0 = time.perf_counter()
        StreamScheduler(streams).run(frames_per_stream,
                                     lambda i, k: imgs[k])
        stream_wall = time.perf_counter() - t0
        client.join()
        per_stream = {s.name: s.stats() for s in streams}
        total = sum(st["frames"] for st in per_stream.values())
        misses = sum(st["deadline_misses"] for st in per_stream.values())
        dropped = sum(st["dropped"] for st in per_stream.values())
        lats = sorted(classify_lat)
        out_scenarios[name] = {
            "sim_compute_ms": charge_s * 1e3,
            "streams": per_stream,
            "aggregate": {
                "frames": total,
                "completed": total - dropped,
                "dropped": dropped,
                "drop_rate": dropped / total,
                "deadline_misses": misses,
                "deadline_miss_rate": misses / total,
            },
            # wall-clock (machine-dependent, never gated): how long the
            # real kernels took to chew through the simulated schedule,
            # and what the contending classify traffic saw
            "wall": {
                "stream_wall_s": stream_wall,
                "frames_per_wall_s": (total - dropped) / stream_wall,
                "classify_requests": len(lats),
                "classify_p50_s": lats[len(lats) // 2] if lats else None,
                "classify_p95_s": (lats[min(len(lats) - 1,
                                            round(0.95 * (len(lats) - 1)))]
                                   if lats else None),
            },
        }
        stats = server.stats()
        out_scenarios[name]["cache"] = stats["cache"]
        server.close()
    payload = {
        "kind": "streaming",
        "networks": list(networks),
        "n_streams": n_streams,
        "fps": fps,
        "frames_per_stream": frames_per_stream,
        "scenarios": out_scenarios,
        "wall_s": time.perf_counter() - t_start,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    for name, sc in out_scenarios.items():
        agg = sc["aggregate"]
        print(f"{name}: {agg['frames']} frames over {n_streams} streams, "
              f"miss rate {agg['deadline_miss_rate']:.3f}, "
              f"dropped {agg['dropped']}, classify p95 "
              f"{sc['wall']['classify_p95_s'] or float('nan'):.3f}s")
    print(f"wrote {path} in {payload['wall_s']:.1f}s")


def emit_quant_json(path, config="resnet18", n_images=8):
    """Accuracy-vs-speed across precisions (the BENCH_quant.json artifact).

    One fp32 reference engine of the tiny config supplies the parameter
    values and the ground-truth logits; each reduced-precision row reuses
    those same values (cast, or int8-quantized) so the sweep isolates
    precision from initialization. Rows:

      * ``float32`` — the reference (agreement 1.0 by construction);
      * ``bfloat16`` / ``float16`` — compute + storage at the reduced
        width, tuned under the dtype-keyed plan (byte terms halve, so
        ``est_time_s`` is the speed side of the trade);
      * ``int8`` — weight-only quantization via ``repro.quant``: int8
        codes + per-channel scales folded into the fused epilogue, fp32
        compute, fp32 plan reused. ``weight_bytes`` carries the ~4x
        storage saving; ``est_time_s`` stays the compute-dtype estimate.

    Everything is seeded, so rows are deterministic on a given platform —
    the CI gate compares agreement/xla-fallback against the committed
    baseline.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get, tiny_variant
    from repro.core import InferenceEngine
    from repro.core.dtypes import KERNEL_DTYPES, with_precision
    from repro.quant import quantization_error, quantize_params

    cfg = tiny_variant(get(config))
    ref = InferenceEngine(cfg)  # fp32 reference: params, plan, logits
    size = cfg.extra["img"]
    images = jax.random.normal(jax.random.key(0), (n_images, size, size, 3))
    ref_logits = np.asarray(ref.run_batch(images), np.float32)
    ref_top1 = ref_logits.argmax(-1)
    ref_max = np.abs(ref_logits).max() + 1e-12

    def cast_params(tree, dt):
        return jax.tree.map(
            lambda x: x.astype(dt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def param_bytes(tree):
        return int(sum(x.size * jnp.dtype(x.dtype).itemsize
                       for x in jax.tree.leaves(tree)))

    def row(name, eng, weight_bytes, extra=None):
        logits = np.asarray(eng.run_batch(images), np.float32)
        plan = eng.plan
        r = {
            "dtype": name,
            "n_images": n_images,
            "top1_agreement": float((logits.argmax(-1) == ref_top1).mean()),
            "logit_rel_err": float(np.abs(logits - ref_logits).max()
                                   / ref_max),
            "est_time_s": sum(c.est_time for c in plan.choices.values()),
            "est_bytes": sum(c.est_bytes for c in plan.choices.values()),
            "weight_bytes": weight_bytes,
            "xla_sites": sorted(n for n, c in plan.choices.items()
                                if c.algorithm == "xla"),
        }
        r.update(extra or {})
        return r

    rows = [row("float32", ref, param_bytes(ref.params))]
    for dt in KERNEL_DTYPES:
        if dt == "float32":
            continue
        cfg_v = with_precision(cfg, dt)
        eng = InferenceEngine(cfg_v, params=cast_params(ref.params, dt))
        rows.append(row(dt, eng, param_bytes(eng.params)))
    qparams, qreport = quantize_params(ref.params)
    qeng = InferenceEngine(cfg, params=qparams, plan=ref.plan)
    conv_w_fp32 = sum(q.codes.size * 4 for q in qreport.values())
    q_storage = sum(q.storage_bytes for q in qreport.values())
    werr = quantization_error(ref.params, qreport)
    rows.append(row(
        "int8", qeng, param_bytes(ref.params) - conv_w_fp32 + q_storage,
        {"quantized_sites": len(qreport),
         "max_weight_rounding_rel_err": max(werr.values())}))

    payload = {"kind": "quant", "config": cfg.name, "n_images": n_images,
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    for r in rows:
        print(f"{r['dtype']:>9}: top1 agreement {r['top1_agreement']:.3f}, "
              f"logit rel err {r['logit_rel_err']:.2e}, "
              f"est {r['est_time_s'] * 1e6:.1f}us, "
              f"weights {r['weight_bytes'] / 1e3:.1f}kB, "
              f"{len(r['xla_sites'])} xla sites")
    print(f"wrote {path}: {len(rows)} precision rows on {cfg.name}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="emit the per-layer plan + proxy timings as JSON "
                         "and exit (CI smoke mode)")
    ap.add_argument("--config", default="resnet18",
                    help="network for --json (tiny variant is used)")
    ap.add_argument("--serve", metavar="PATH",
                    help="run the micro-batched serving bench and emit "
                         "throughput/latency JSON (BENCH_serving.json)")
    ap.add_argument("--stream", metavar="PATH",
                    help="run the multi-stream deadline bench and emit "
                         "per-stream miss-rate JSON (BENCH_streaming.json)")
    ap.add_argument("--quant", metavar="PATH",
                    help="run the precision sweep (fp32/bf16/fp16/int8) and "
                         "emit the accuracy-vs-speed JSON (BENCH_quant.json)")
    args = ap.parse_args(argv)
    if args.json:
        emit_json(args.json, config=args.config)
        return
    if args.serve:
        emit_serving_json(args.serve)
        return
    if args.stream:
        emit_streaming_json(args.stream)
        return
    if args.quant:
        emit_quant_json(args.quant, config=args.config)
        return

    t0 = time.time()
    from benchmarks import conv_algorithms, conv_arith, conv_memory, roofline

    _section("paper Table 3: global-memory traffic (analytic vs measured)")
    conv_memory.main()

    _section("paper Fig. 5: algorithm x layer x device (roofline cost model)")
    conv_algorithms.main()

    _section("paper Table 4: arithmetic profile + kernel wall (interpret)")
    conv_arith.main()

    _section("autotuner choices per ResNet layer (paper's tuning library)")
    from repro.core import ConvSpec, select
    from repro.configs.resnet import PAPER_CONV_LAYERS

    print("layer,algorithm,est_us_v5e,est_bytes_MB,vmem_MB")
    for layer in PAPER_CONV_LAYERS:
        ch = select(ConvSpec(h=layer.h, w=layer.w, c=layer.c_in, k=layer.c_out))
        print(f"{layer.name},{ch.algorithm},{ch.est_time * 1e6:.2f},"
              f"{ch.est_bytes / 1e6:.2f},{ch.vmem / 2 ** 20:.2f}")

    _section("roofline (from dry-run artifacts)")
    roofline.main()

    print(f"\n# benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
