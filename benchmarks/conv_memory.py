"""Paper Table 3 reproduction: global-memory traffic per algorithm.

The paper measures MB read/written per kernel on Vega 8 (codeXL profile,
conv4.x: C=K=256, 14x14, fp32). We reproduce those numbers ANALYTICALLY
from each algorithm's data movement — the core claim (ILP-M touches the
least global memory; im2col's unrolled matrix round-trips HBM) is validated
if the analytic bytes land near the measured profile.
"""
from __future__ import annotations

from repro.configs.resnet import PAPER_CONV_LAYERS

# paper Table 3 (conv4.x), MB — (read, write) per kernel phase
PAPER_TABLE3 = {
    "im2col_im2col": (0.20, 1.73),
    "im2col_gemm": (9.27, 0.20),
    "libdnn_conv": (2.48, 0.20),
    "winograd_trans_from_image": (0.20, 0.77),
    "winograd_gemm_x16": (4.91, 0.77),
    "winograd_trans_to_output": (0.77, 0.19),
    "direct_conv": (2.60, 0.19),
    "ILP-M_conv": (2.46, 0.20),
}

MB = 1e6


def analytic_traffic(layer, el=4):
    """Analytic (read_MB, write_MB) per algorithm phase for one layer."""
    H, W, C, K, R, S = layer.h, layer.w, layer.c_in, layer.c_out, layer.r, layer.s
    img = H * W * C * el
    filt = R * S * C * K * el
    out = H * W * K * el
    patches = H * W * R * S * C * el
    v = 16 * (H // 2) * (W // 2) * C * el
    m = 16 * (H // 2) * (W // 2) * K * el
    u = 16 * C * K * el
    return {
        "im2col_im2col": (img / MB, patches / MB),
        "im2col_gemm": ((patches + filt) / MB, out / MB),
        "libdnn_conv": ((img + filt) / MB, out / MB),
        "winograd_trans_from_image": (img / MB, v / MB),
        "winograd_gemm_x16": ((v + u) / MB, m / MB),
        "winograd_trans_to_output": (m / MB, out / MB),
        "direct_conv": ((img + filt) / MB, out / MB),
        "ILP-M_conv": ((img + filt) / MB, out / MB),
    }


def run(layer_name="conv4.x"):
    layer = next(l for l in PAPER_CONV_LAYERS if l.name == layer_name)
    ours = analytic_traffic(layer)
    rows = []
    for k, (pr, pw) in PAPER_TABLE3.items():
        ar, aw = ours[k]
        rows.append({
            "kernel": k, "paper_read_MB": pr, "paper_write_MB": pw,
            "analytic_read_MB": round(ar, 2), "analytic_write_MB": round(aw, 2),
            "read_ratio": round(ar / pr, 2) if pr else None,
        })
    # headline: ILP-M read reduction vs im2col total (paper: 74.0%)
    im2col_total = ours["im2col_im2col"][0] + ours["im2col_gemm"][0]
    reduction = 1 - ours["ILP-M_conv"][0] / im2col_total
    return rows, {"ilpm_read_reduction_vs_im2col": round(reduction, 3),
                  "paper_claim": 0.740}


def main():
    rows, headline = run()
    print("kernel,paper_read_MB,analytic_read_MB,paper_write_MB,analytic_write_MB")
    for r in rows:
        print(f"{r['kernel']},{r['paper_read_MB']},{r['analytic_read_MB']},"
              f"{r['paper_write_MB']},{r['analytic_write_MB']}")
    print(f"# ILP-M read reduction vs im2col: {headline['ilpm_read_reduction_vs_im2col']}"
          f" (paper: {headline['paper_claim']})")


if __name__ == "__main__":
    main()
