"""Paper Table 4 analogue: arithmetic profile + interpret-mode wall clock.

Two parts:
  1. analytic op counts per algorithm (useful MACs, transform adds, index
     overhead) — the structural quantities behind the paper's instruction
     counts;
  2. interpret-mode wall time of the actual Pallas kernels on small shapes
     (CPU emulation: RELATIVE sanity only, not TPU performance — the
     roofline benchmarks carry the perf claims).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.resnet import PAPER_CONV_LAYERS
from repro.kernels import ops, ref

# paper Table 4, conv4.x (10^4 instructions)
PAPER_TABLE4 = {
    "im2col": {"vector": 248.32 + 4707.2, "scalar": 343.68 + 785.76},
    "libdnn": {"vector": 6289.12, "scalar": 1277.28},
    "winograd": {"vector": 112.16 + 2469.12 + 52.8,
                 "scalar": 27.84 + 447.36 + 2.88},
    "direct": {"vector": 5711.52, "scalar": 990.88},
    "ilpm": {"vector": 3935.2, "scalar": 43.84},
}


def analytic_ops(layer):
    H, W, C, K, R, S = layer.h, layer.w, layer.c_in, layer.c_out, layer.r, layer.s
    macs = H * W * R * S * C * K
    wino_macs = 16 * (H // 2) * (W // 2) * C * K
    wino_adds = 2 * 16 * 4 * (H // 2) * (W // 2) * (C + K)  # B^T d B + A^T m A
    return {
        "im2col": {"macs": macs, "extra": H * W * R * S * C},   # unroll copies
        "libdnn": {"macs": macs, "extra": H * W * R * S * C * (K // 128 or 1)},
        "winograd": {"macs": wino_macs, "extra": wino_adds},
        "direct": {"macs": macs, "extra": R * S * C * K},       # filter restage
        "ilpm": {"macs": macs, "extra": 0},
    }


def wall_clock(h=14, w=14, c=32, k=64, repeats=3):
    """Interpret-mode relative wall times (CPU emulation of the kernels)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (1, h, w, c))
    wgt = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, c, k))
    xp = ref.pad_same(x, 3, 3)
    out = {}
    for name in ops.DENSE_ALGORITHMS:
        fn = ops.ALGORITHMS[name]
        try:
            fn(xp, wgt, impl="pallas").block_until_ready()
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn(xp, wgt, impl="pallas").block_until_ready()
            out[name] = (time.perf_counter() - t0) / repeats * 1e6
        except Exception as e:  # noqa: BLE001
            out[name] = None
    return out


def main():
    layer = PAPER_CONV_LAYERS[2]  # conv4.x, the paper's profile subject
    ops_count = analytic_ops(layer)
    print("algorithm,analytic_MACs,analytic_extra_ops,"
          "paper_vector_inst_e4,paper_scalar_inst_e4")
    for a, d in ops_count.items():
        p = PAPER_TABLE4[a]
        print(f"{a},{d['macs']},{d['extra']},{p['vector']},{p['scalar']}")
    wc = wall_clock()
    print("# interpret-mode us/call (CPU emulation, relative only):",
          {k: (round(v, 1) if v else None) for k, v in wc.items()})


if __name__ == "__main__":
    main()
