"""Device constants for the cost-model benchmarks.

The paper's three platforms (Table 1) + our target TPU v5e. Peak FLOP/s for
the paper's GPUs ≈ ALUs x 2 (FMA) x clock.
"""

DEVICES = {
    # name: (peak_flops, mem_bw_bytes_s)
    "mali_g76": (240 * 2 * 0.72e9, 33.3e9),     # Arm Mali-G76 MP10, LPDDR4x2
    "vega8": (512 * 2 * 1.1e9, 25.0e9),         # AMD Radeon Vega 8, DDR4 x1
    "radeon_vii": (3840 * 2 * 1.4e9, 1024e9),   # AMD Radeon VII, HBM2
    "tpu_v5e": (197e12, 819e9),                 # per chip, bf16
}
