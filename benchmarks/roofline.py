"""§Roofline table builder: reads dryrun_results.json -> markdown + CSV.

Per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and the
per-device HBM high-water mark (peak + args) against the 16 GB budget.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "dryrun_results.json"


def load(path=RESULTS):
    if not Path(path).exists():
        return []
    return json.loads(Path(path).read_text())


def table(results, mesh="16x16"):
    rows = []
    for r in results:
        if "error" in r or r["mesh"] != mesh:
            continue
        t = r["roofline_s"]
        pd = r["per_device"]
        hbm = (pd["peak_bytes"] + pd["argument_bytes"]) / 2 ** 30
        frac = max(t.values()) and (t["compute"] / max(t.values()))
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_s": f"{t['compute']:.3e}",
            "t_memory_s": f"{t['memory']:.3e}",
            "t_collective_s": f"{t['collective']:.3e}",
            "bottleneck": r["bottleneck"],
            "roofline_frac": f"{frac:.3f}",
            "useful_flops": (f"{r['useful_flops_ratio']:.2f}"
                             if r.get("useful_flops_ratio") else "-"),
            "hbm_GiB": f"{hbm:.2f}",
        })
    return rows


def to_markdown(rows):
    if not rows:
        return "(no dry-run results found)"
    cols = list(rows[0])
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(out)


def main():
    results = load()
    for mesh in ("16x16", "2x16x16"):
        rows = table(results, mesh)
        print(f"\n## mesh {mesh} ({len(rows)} cells)\n")
        print(to_markdown(rows))
    fails = [r for r in results if "error" in r]
    print(f"\n# {len(results) - len(fails)}/{len(results)} cells passed")
    for f in fails:
        print("# FAIL", f["arch"], f["shape"], f["mesh"], f["error"][:120])


if __name__ == "__main__":
    main()
