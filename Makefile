# Tier-1 verification: the command CI and the roadmap gate on.
PYTHON ?= python

.PHONY: verify
verify:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

.PHONY: examples
examples:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py
	PYTHONPATH=src $(PYTHON) examples/mobile_pipeline.py

.PHONY: bench
bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/conv_algorithms.py

.PHONY: bench-mobilenet
bench-mobilenet:
	PYTHONPATH=src:. $(PYTHON) benchmarks/mobilenet_layers.py

# Machine-readable per-layer bench (tiny config) — the CI perf-trajectory
# artifact: per-site algorithm, tuned params, cost-model estimates, and
# interpret-mode proxy timings.
.PHONY: bench-json
bench-json:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py --json BENCH_conv.json

# Validate every local link/anchor in README.md and docs/ (CI step).
.PHONY: docs-check
docs-check:
	$(PYTHON) tools/check_docs_links.py README.md docs
