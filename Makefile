# Tier-1 verification: the command CI and the roadmap gate on.
PYTHON ?= python

.PHONY: verify
verify:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

# Fast-fail lint gate (ruff, critical rules only — see ruff.toml). CI runs
# this as its first job, before the test matrix.
.PHONY: lint
lint:
	$(PYTHON) -m ruff check .

.PHONY: examples
examples:
	PYTHONPATH=src $(PYTHON) examples/quickstart.py
	PYTHONPATH=src $(PYTHON) examples/mobile_pipeline.py

.PHONY: bench
bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/conv_algorithms.py

.PHONY: bench-mobilenet
bench-mobilenet:
	PYTHONPATH=src:. $(PYTHON) benchmarks/mobilenet_layers.py

# Machine-readable per-layer bench (tiny config) — the CI perf-trajectory
# artifact: per-site algorithm, tuned params, cost-model estimates, and
# interpret-mode proxy timings.
.PHONY: bench-json
bench-json:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py --json BENCH_conv.json

# Compare the fresh BENCH_conv.json against the committed baseline; fails
# on any tuned-site -> xla fallback or a >25% interpret-proxy slowdown.
.PHONY: bench-compare
bench-compare:
	$(PYTHON) tools/compare_bench.py benchmarks/baseline/BENCH_conv.json BENCH_conv.json

# Micro-batched serving scenarios (>= 2 networks, one shared EngineCache
# process): steady throughput/latency, the overload scenario (bounded
# queue at ~2x+ capacity, typed shedding), and the load-sweep SLO curve
# (offered-QPS ladder x p50/p95/p99 + shed rate) -> BENCH_serving.json.
.PHONY: bench-serving
bench-serving:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py --serve BENCH_serving.json

# Alias that names the sweep: regenerate the artifact and run the
# SLO-curve gate against the committed baseline in one step.
.PHONY: bench-sweep
bench-sweep: bench-serving bench-compare-serving

# Gate the fresh BENCH_serving.json against the committed baseline: fails
# if the overload scenario stops shedding (unbounded queue again), any
# accepted Ticket never resolved, accepted p95 exceeds the queue-depth
# bound, shed_rate drifts outside the band, or the sweep's SLO curve
# breaks (shed below saturation, p95 over bound, non-monotone shed).
.PHONY: bench-compare-serving
bench-compare-serving:
	$(PYTHON) tools/compare_bench.py benchmarks/baseline/BENCH_serving.json BENCH_serving.json

# The chaos suite alone: scripted FaultInjector runs over retry/breaker/
# degrade/shed paths, the fault-tolerance runtime tests, and the
# wire-level protocol faults (fuzzed frames, client disconnects).
.PHONY: chaos
chaos:
	PYTHONPATH=src $(PYTHON) -m pytest -q tests/test_chaos.py tests/test_fault_tolerance.py tests/test_protocol.py

# Multi-stream deadline bench: K simulated-clock 30 fps streams (engine
# leases) + on-demand classify contention -> BENCH_streaming.json.
.PHONY: bench-streaming
bench-streaming:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py --stream BENCH_streaming.json

# Gate the fresh BENCH_streaming.json against the committed baseline:
# fails if any scenario's deadline-miss or frame-drop rate regresses
# (the simulated-clock numbers are deterministic; tolerance is 0).
.PHONY: bench-compare-streaming
bench-compare-streaming:
	$(PYTHON) tools/compare_bench.py benchmarks/baseline/BENCH_streaming.json BENCH_streaming.json

# Precision sweep: fp32 reference vs bf16/fp16/int8-weight variants of the
# same parameters -> accuracy-vs-speed rows in BENCH_quant.json.
.PHONY: bench-quant
bench-quant:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py --quant BENCH_quant.json

# Gate the fresh BENCH_quant.json against the committed baseline: fails on
# a top-1 agreement drop, a logit-error blowup, or any site newly falling
# back to xla in a reduced precision.
.PHONY: bench-compare-quant
bench-compare-quant:
	$(PYTHON) tools/compare_bench.py benchmarks/baseline/BENCH_quant.json BENCH_quant.json

# Validate every local link/anchor in README.md and docs/ (CI step).
.PHONY: docs-check
docs-check:
	$(PYTHON) tools/check_docs_links.py README.md docs
