"""Shared quantization primitives: int8 codes + scales, epilogue folding.

Two consumers share these rules:

  * **inference** (this module's main job): per-output-channel symmetric
    int8 weight quantization for the CNN engines. The trick that makes it
    ride the existing kernels unchanged is *epilogue folding*: for
    per-channel scales ``s_k``,

        conv(x, codes_k · s_k) = conv(x, codes_k) · s_k

    so the dequantization multiply is exactly the fused ``y·scale + bias``
    epilogue every kernel already applies inside its output write — the
    folded-BN ``scale`` vector just absorbs ``s_k``. No new kernel, no
    extra HBM pass, and the int8 codes (integers ≤ 127) are exact in any
    float compute dtype, so accumulate-in-fp32 semantics are unchanged.
  * **training** (``repro.optim.compression``): per-tensor symmetric int8
    gradient compression for the cross-pod all-reduce — same
    quantize/dequantize core, one scalar scale instead of (K,).

Storage accounting for the cost model / benchmarks uses
``repro.core.dtypes.element_size("int8") == 1``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def quantize(x):
    """x -> (int8 codes, fp32 scale). Symmetric per-tensor."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(x32).max(), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize(codes, scale):
    """Inverse of ``quantize`` (also per-channel: scale broadcasts)."""
    return codes.astype(jnp.float32) * scale


def quantize_per_channel(w, axis: int = -1):
    """w -> (int8 codes, fp32 scales along ``axis``). Symmetric.

    For HWIO conv filters ``axis=-1`` is the output-channel axis K — one
    scale per output channel, the granularity the fused epilogue's (K,)
    ``scale`` vector can absorb exactly.
    """
    w32 = w.astype(jnp.float32)
    reduce_axes = tuple(i for i in range(w32.ndim)
                        if i != axis % w32.ndim)
    scales = jnp.maximum(jnp.abs(w32).max(axis=reduce_axes), 1e-12) / 127.0
    shape = [1] * w32.ndim
    shape[axis % w32.ndim] = -1
    codes = jnp.clip(jnp.round(w32 / scales.reshape(shape)),
                     -127, 127).astype(jnp.int8)
    return codes, scales


@dataclass(frozen=True)
class QuantizedConv:
    """One conv site's int8 weights: codes (R,S,Cg,K) + per-channel scales
    (K,). ``storage_bytes`` is what actually ships (codes int8 + fp32
    scales) — the 4x weight-traffic saving the bench accounts for."""

    codes: jax.Array   # int8
    scales: jax.Array  # fp32, (K,)

    @property
    def storage_bytes(self) -> int:
        return self.codes.size + 4 * self.scales.size


def _is_conv_site(node) -> bool:
    return (isinstance(node, dict) and {"w", "scale", "bias"} <= node.keys()
            and getattr(node["w"], "ndim", 0) == 4)


def quantize_params(params, *, compute_dtype=None):
    """Quantize every conv site of a CNN param tree to int8 weights with
    the per-channel scales folded into the fused epilogue.

    Returns ``(qparams, report)``:

      * ``qparams`` — a param tree the *unchanged* model forward runs:
        each conv ``w`` is replaced by its int8 codes cast back to
        ``compute_dtype`` (exact — the codes are integers ≤ 127), and the
        site's folded-BN ``scale`` becomes ``scale · s_k``, so every
        kernel's existing in-kernel epilogue performs the dequantization
        multiply for free. ``bias`` is untouched (the epilogue applies it
        after the scale, matching ``(conv·s_k)·scale + bias``).
      * ``report`` — {site name: QuantizedConv} carrying the true int8
        codes + scales (storage/wire format, and what the bench's
        weight-byte accounting reads).

    Non-conv leaves (the fc head, 1D params) pass through unchanged —
    keeping the classifier head in float is standard practice and the
    head is traffic-noise anyway.
    """
    report: dict[str, QuantizedConv] = {}

    def walk(node, path):
        if _is_conv_site(node):
            w = node["w"]
            dt = compute_dtype or w.dtype
            codes, scales = quantize_per_channel(w, axis=-1)
            report[".".join(path)] = QuantizedConv(codes, scales)
            out = dict(node)
            out["w"] = codes.astype(dt)
            # epilogue folding: the kernels' fused y·scale + bias applies
            # the dequantization multiply (scales are kept fp32; the
            # epilogue operands are materialized fp32 anyway)
            out["scale"] = node["scale"].astype(jnp.float32) * scales
            return out
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return node

    return walk(params, ()), report


def quantization_error(params, qreport) -> dict:
    """Max |w - dequant(w)| / max |w| per quantized site — the analytic
    weight-rounding error the accuracy row contextualizes."""
    out = {}
    for name, q in qreport.items():
        node = params
        for part in name.split("."):
            node = node[part]
        w32 = node["w"].astype(jnp.float32)
        err = jnp.abs(w32 - dequantize(q.codes, q.scales)).max()
        out[name] = float(err / (jnp.abs(w32).max() + 1e-12))
    return out
