"""ResNet configs — the paper's own evaluation network (Table 2).

All non-1x1 conv layers of ResNet are 3x3; the paper benchmarks conv2.x
through conv5.x on 224x224 ImageNet inputs (so 56/28/14/7 spatial sizes).
These configs drive the conv-algorithm benchmarks and the single-image
inference engine examples.
"""
from dataclasses import dataclass, field

from repro.configs.base import ArchConfig, register


@dataclass(frozen=True)
class ConvLayerSpec:
    """One benchmarked conv layer: C in, K out, HxW spatial, RxS filter."""
    name: str
    c_in: int
    c_out: int
    h: int
    w: int
    r: int = 3
    s: int = 3
    stride: int = 1
    count: int = 1  # occurrences in the net


# Paper Table 2: the 3x3 conv layers of ResNet (C=K, square images).
PAPER_CONV_LAYERS = (
    ConvLayerSpec("conv2.x", 64, 64, 56, 56),
    ConvLayerSpec("conv3.x", 128, 128, 28, 28),
    ConvLayerSpec("conv4.x", 256, 256, 14, 14),
    ConvLayerSpec("conv5.x", 512, 512, 7, 7),
)

# Per-variant block counts for the basic-block nets (paper Table 2 columns).
RESNET_BLOCKS = {
    "resnet18": (2, 2, 2, 2),
    "resnet34": (3, 4, 6, 3),
    "resnet50": (3, 4, 6, 3),
    "resnet101": (3, 4, 23, 3),
    "resnet152": (3, 8, 36, 3),
}

RESNET18 = register(ArchConfig(
    name="resnet18",
    family="cnn",
    num_layers=18,
    vocab_size=1000,  # ImageNet classes
    use_ilpm_conv=True,
    dtype="float32",
    param_sharding="replicated",
    extra={"blocks": (2, 2, 2, 2), "bottleneck": False, "img": 224},
))

RESNET50 = register(ArchConfig(
    name="resnet50",
    family="cnn",
    num_layers=50,
    vocab_size=1000,
    use_ilpm_conv=True,
    dtype="float32",
    param_sharding="replicated",
    extra={"blocks": (3, 4, 6, 3), "bottleneck": True, "img": 224},
))
