"""internvl2-26b — InternVL2 26B VLM: InternViT-6B frontend + InternLM2-20B LM.

[arXiv:2404.16821; hf] backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. Per the assignment spec the modality frontend is a STUB —
``input_specs()`` feeds precomputed patch embeddings concatenated with token
embeddings. The real patch-embed conv path exists in models/frontends.py and
routes through the ILP-M conv when enabled.
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_26B = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attn_impl="gqa",
    act="swiglu",
    frontend="vit_stub",
    frontend_tokens=256,  # 448px / 14 patch -> 1024 -> pixel-shuffle x0.25
    param_sharding="fsdp",
    optimizer="adafactor",
))
