"""granite-3-2b — IBM Granite 3.0 2B base, dense GQA LM.

[hf:ibm-granite/granite-3.0-2b-base; hf] 40L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=49155. ILP-M inapplicable (no conv).
"""
from repro.configs.base import ArchConfig, register

GRANITE_3_2B = register(ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    attn_impl="gqa",
    act="swiglu",
    tie_embeddings=True,
    param_sharding="fsdp",
))
