"""qwen2-0.5b — Qwen2 0.5B dense GQA LM with QKV bias.

[arXiv:2407.10671; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
ILP-M inapplicable (no conv).
"""
from repro.configs.base import ArchConfig, register

QWEN2_0_5B = register(ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    attn_impl="gqa",
    act="swiglu",
    rope_theta=1_000_000.0,
    param_sharding="fsdp",
))
