"""jamba-1.5-large-398b — AI21 Jamba 1.5 Large: Mamba+attention hybrid MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2. Interleave: 1 attention layer per 8
(attention at period offset 4), MoE every other layer. Mamba sublayers carry
the depthwise causal conv1d -> **ILP-M technique applies**
(kernels/causal_conv1d.py). Hybrid => sub-quadratic path: runs long_500k
(only 9/72 layers hold a 512k KV cache).
"""
from repro.configs.base import ArchConfig, register

JAMBA_1_5_LARGE = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_impl="gqa",
    attn_layer_period=8,
    attn_layer_offset=4,
    num_experts=16,
    num_shared_experts=0,
    top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    moe_layer_offset=1,
    ssm_state=64,
    ssm_conv_k=4,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_ngroups=8,
    act="swiglu",
    supports_500k=True,
    use_ilpm_conv=True,
    param_sharding="fsdp",
    optimizer="adafactor",  # 398B total params
    param_dtype="bfloat16",  # §Perf J2: halves param HBM + wire bytes
))
