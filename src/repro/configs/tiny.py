"""Reduced same-family configs for CPU smoke tests.

Each assigned arch gets a faithful miniature: same family/block plan
(GQA ratios, MLA latents, MoE routing, hybrid interleave, enc-dec split),
small widths/depths/vocab so one fwd/train step runs on a single CPU device.
"""
from repro.configs.base import ArchConfig


def tiny_variant(cfg: ArchConfig) -> ArchConfig:
    """Derive the reduced smoke config of the same family."""
    kw: dict = dict(
        name=cfg.name + "-tiny",
        dtype="float32",
        param_dtype="float32",
        remat="none",
        vocab_size=min(cfg.vocab_size, 256) or 256,
        attn_chunk=64,
    )
    if cfg.family == "cnn":
        extra = {**cfg.extra, "img": 32}
        if "blocks" in extra:  # resnet family
            extra["blocks"] = (1, 1, 1, 1)
        if "settings" in extra:  # mobilenet family: one block per stage,
            # keeping the structural variety (t=1 stage, strided stages,
            # a residual-eligible stride-1 stage)
            extra.update(settings=((1, 16, 1, 1), (6, 24, 1, 2),
                                   (6, 24, 1, 1), (6, 40, 1, 2)),
                         stem=16, head=64)
        return cfg.replace(**{**kw, "extra": extra})

    if cfg.attn_impl == "mla":
        kw.update(num_heads=4, num_kv_heads=4, kv_lora_rank=32, q_lora_rank=48,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, head_dim=16)
    elif cfg.attn_impl == "gqa":
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kw.update(num_heads=4, num_kv_heads=max(1, 4 // min(ratio, 4)), head_dim=16)

    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_ngroups=min(cfg.ssm_ngroups, 2),
                  ssd_chunk=16)

    if cfg.num_experts:
        kw.update(num_experts=8, top_k=min(cfg.top_k, 2),
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  moe_d_ff=64)

    if cfg.family == "hybrid":
        # one full interleave period + change-of-period coverage
        kw.update(num_layers=cfg.attn_layer_period,
                  attn_layer_offset=min(cfg.attn_layer_offset, cfg.attn_layer_period - 1))
    elif cfg.is_encoder_decoder:
        kw.update(num_layers=2, num_encoder_layers=2, encoder_seq=16, frontend_tokens=16)
    else:
        kw.update(num_layers=2 + cfg.first_dense_layers)

    kw.update(d_model=64, d_ff=128 if cfg.d_ff else 0)
    if cfg.frontend == "vit_stub":
        kw.update(frontend_tokens=8)
    return cfg.replace(**kw)
