"""whisper-base — OpenAI Whisper base: encoder-decoder audio transformer.

[arXiv:2212.04356; unverified] 6L(enc)+6L(dec) d_model=512 8H d_ff=2048
vocab=51865. Conv frontend (2x conv1d stride 1,2) is a STUB per assignment —
``input_specs()`` provides precomputed 1500 frame embeddings. The real stem
lives in models/frontends.py and uses the ILP-M conv1d when enabled.
Full MHA (kv=8 == heads), GELU MLP, learned positions — the paper-faithful
whisper block. Decode shapes exercise self-attn KV cache + fixed cross-attn
cache.
"""
from repro.configs.base import ArchConfig, register

WHISPER_BASE = register(ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    num_encoder_layers=6,
    is_encoder_decoder=True,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    attn_impl="gqa",
    act="gelu_mlp",
    pos_emb="learned",
    frontend="audio_stub",
    frontend_tokens=1500,
    encoder_seq=1500,
    param_sharding="fsdp",
))
