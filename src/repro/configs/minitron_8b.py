"""minitron-8b — NVIDIA Minitron 8B (pruned Nemotron-4 15B).

[arXiv:2407.14679; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Squared-ReLU MLP in the original; we keep the assignment's shape fields and
llama-style SwiGLU trunk (shape-identical FLOPs profile), large 256k vocab is
the distinguishing stressor (vocab-sharded embed/unembed).
ILP-M inapplicable (no conv).
"""
from repro.configs.base import ArchConfig, register

MINITRON_8B = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    attn_impl="gqa",
    act="swiglu",
    param_sharding="fsdp",
))
