"""Config registry: importing this package registers every assigned arch."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeSpec,
    applicable_shapes,
    get,
    names,
    register,
)
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    granite_3_2b,
    granite_8b,
    granite_moe_3b,
    internvl2_26b,
    jamba_1_5_large,
    mamba2_370m,
    minitron_8b,
    mobilenet,
    qwen2_0_5b,
    resnet,
    whisper_base,
)
from repro.configs.tiny import tiny_variant  # noqa: F401

# The 10 assigned LM-pool architectures (resnet* are the paper's own nets).
ASSIGNED = (
    "granite-8b",
    "granite-3-2b",
    "qwen2-0.5b",
    "minitron-8b",
    "mamba2-370m",
    "deepseek-v2-236b",
    "granite-moe-3b-a800m",
    "internvl2-26b",
    "jamba-1.5-large-398b",
    "whisper-base",
)
