"""MobileNet configs — the mobile-GPU workload family the paper targets.

MobileNetV2 (Sandler et al. 2018) inverted-residual settings: each row is
(t, c, n, s) = (expansion, output channels, block repeats, first-block
stride). Depthwise + pointwise layers dominate this net's inference time
(Zhang et al. 2020), which is what the grouped kernel family exists for.
"""
from repro.configs.base import ArchConfig, register

# The paper-standard MobileNetV2 1.0x table.
MOBILENET_V2_SETTINGS = (
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
)

MOBILENET_V2 = register(ArchConfig(
    name="mobilenet_v2",
    family="cnn",
    num_layers=53,
    vocab_size=1000,  # ImageNet classes
    use_ilpm_conv=True,
    dtype="float32",
    param_sharding="replicated",
    extra={"arch": "mobilenet", "img": 224, "stem": 32, "head": 1280,
           "settings": MOBILENET_V2_SETTINGS},
))
