"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig`` — a frozen
dataclass holding the exact published hyperparameters plus the knobs the
framework needs (sharding policy, remat policy, attention implementation,
optimizer choice). ``tiny()`` derives the reduced smoke-test config of the
same family, as required by the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn

    # --- transformer trunk ---
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"  # rope | learned | none
    act: str = "swiglu"  # swiglu | gelu_mlp

    # --- attention ---
    attn_impl: str = "gqa"  # gqa | mla | none
    attn_chunk: int = 2048  # kv/q chunk for online-softmax attention
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1  # MoE every k-th layer
    moe_layer_offset: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    moe_dispatch: str = "scatter"  # scatter | dense | alltoall

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_conv_k: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssd_chunk: int = 256

    # --- hybrid interleave (Jamba) ---
    attn_layer_period: int = 0  # 1 attention layer per this many layers
    attn_layer_offset: int = 0

    # --- encoder/decoder ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30s of audio -> 1500 frames

    # --- modality frontend (stub per assignment spec) ---
    frontend: str = "none"  # none | vit_stub | audio_stub
    frontend_tokens: int = 0  # stub frame/patch count folded into the seq

    # --- numerics / policy ---
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # stored master dtype
    remat: str = "full"  # none | full
    param_sharding: str = "fsdp"  # fsdp | tp | replicated
    optimizer: str = "adamw"  # adamw | adafactor
    opt_state_dtype: str = "float32"
    supports_500k: bool = False  # sub-quadratic decode path exists
    use_ilpm_conv: bool = False  # paper technique applies to this arch

    extra: dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        from repro.models import registry as _registry

        return _registry.count_params(self)

    def active_params(self) -> int:
        from repro.models import registry as _registry

        return _registry.count_params(self, active_only=True)


# ----------------------------------------------------------------------
# registry

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    # import side-effect registration
    import repro.configs  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# input shapes assigned to the LM pool (per-assignment spec)

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeSpec]:
    """Per-assignment skip rules.

    ``long_500k`` needs a sub-quadratic decode path: run only for SSM /
    hybrid archs (see DESIGN.md §Arch-applicability).
    """
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_500k:
            continue
        out.append(s)
    return out
