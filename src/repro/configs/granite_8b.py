"""granite-8b — IBM Granite Code 8B, llama-architecture dense LM.

[arXiv:2405.04324; hf] 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
No convolution in this family: the paper's ILP-M technique is inapplicable
(DESIGN.md §Arch-applicability); runs as pure attention+SwiGLU substrate.
"""
from repro.configs.base import ArchConfig, register

GRANITE_8B = register(ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    attn_impl="gqa",
    act="swiglu",
    param_sharding="fsdp",
    optimizer="adamw",
))
