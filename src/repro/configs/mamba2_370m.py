"""mamba2-370m — Mamba-2 370M, attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified] 48L d_model=1024 vocab=50280 ssm_state=128.
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSM heads, depthwise causal
conv1d k=4 — **the paper's ILP-M technique applies to this conv**
(kernels/causal_conv1d.py). Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

MAMBA2_370M = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_impl="none",
    pos_emb="none",
    ssm_state=128,
    ssm_conv_k=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssd_chunk=256,
    tie_embeddings=True,
    supports_500k=True,
    use_ilpm_conv=True,
    param_sharding="fsdp",
))
