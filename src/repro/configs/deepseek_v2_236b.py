"""deepseek-v2-236b — DeepSeek-V2 236B MoE with Multi-head Latent Attention.

[arXiv:2405.04434; hf] 60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536,
qk_nope=128, qk_rope=64, v=128), vocab=102400. MoE: 2 shared + 160 routed
experts, top-6, expert d_ff=1536; first layer dense (d_ff=12288).
ILP-M inapplicable (no conv); exercised as the MLA/MoE substrate and the
expert-parallel collective stressor.
"""
from repro.configs.base import ArchConfig, register

DEEPSEEK_V2_236B = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head K/V decompressed from the shared latent
    head_dim=128,      # v_head_dim (qk uses nope+rope = 192)
    d_ff=12288,        # dense (first layer) FFN width
    vocab_size=102400,
    attn_impl="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    moe_layer_period=1,
    first_dense_layers=1,
    act="swiglu",
    param_sharding="fsdp",
    optimizer="adafactor",  # 236B: factored 2nd moment to fit HBM (DESIGN §5)
    param_dtype="bfloat16",  # §Perf J2: halves param HBM + wire bytes
))
