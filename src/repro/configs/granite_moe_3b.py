"""granite-moe-3b-a800m — IBM Granite 3.0 3B-A800M MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf] 32L d_model=1536
24H (GQA kv=8) vocab=49155, MoE 40 experts top-8, expert d_ff=512.
ILP-M inapplicable (no conv).
"""
from repro.configs.base import ArchConfig, register

GRANITE_MOE_3B = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    attn_impl="gqa",
    num_experts=40,
    num_shared_experts=0,
    top_k=8,
    moe_d_ff=512,
    moe_layer_period=1,
    act="swiglu",
    tie_embeddings=True,
    param_sharding="fsdp",
))
