from repro.checkpoint.ckpt import CheckpointManager  # noqa: F401
