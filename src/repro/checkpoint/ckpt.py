"""Sharded, async, integrity-checked checkpointing.

Layout per step:  <dir>/step_<N>/
    shard_<proc>.npz   — flattened pytree leaves owned by this process
    META.json          — step, tree paths, shapes, dtypes, digest per shard
    COMMIT             — written last; a checkpoint without COMMIT is torn
                         and ignored on restore (atomicity on restart).

Single-process here; the per-process shard split is the multi-host layout
(each host writes its addressable shards independently — no cross-host
traffic at save time), which is what the 1000-node deployment needs.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    else:
        yield "/".join(prefix), tree


def _unflatten(pairs):
    root: dict = {}
    for path, val in pairs:
        node = root
        keys = path.split("/")
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------ save
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._pending.start()
        else:
            self._write(step, host_tree)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree):
        proc = jax.process_index()
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = dict(_flatten(host_tree))
        shard = tmp / f"shard_{proc}.npz"
        np.savez(shard, **flat)
        digest = hashlib.sha256(shard.read_bytes()).hexdigest()
        meta = {"step": step,
                "paths": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                "digest": {f"shard_{proc}.npz": digest}}
        (tmp / "META.json").write_text(json.dumps(meta))
        (tmp / "COMMIT").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, verify: bool = True):
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "META.json").read_text())
        proc = jax.process_index()
        shard = d / f"shard_{proc}.npz"
        if verify:
            digest = hashlib.sha256(shard.read_bytes()).hexdigest()
            want = meta["digest"].get(shard.name)
            if want and digest != want:
                raise IOError(f"checkpoint {d} failed integrity check")
        with np.load(shard) as z:
            tree = _unflatten([(k, z[k]) for k in z.files])
        return step, tree
