"""Production train driver.

Wires the full stack: config -> mesh -> sharded state -> resilient train
loop (checkpoint/restart, straggler watch, deterministic data). On real
TPU pods this runs under `python -m repro.launch.train --arch ... --mesh
16x16`; on this CPU container it runs the reduced configs end-to-end.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get, tiny_variant
from repro.data import TokenPipeline
from repro.launch import steps
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.runtime import StragglerWatch, resilient_train
from repro.sharding.rules import rules_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["local", "pod", "multipod"],
                    default="local")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get(args.arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    rules = rules_for(cfg, mesh)

    ckpt = CheckpointManager(args.ckpt_dir)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed)

    with mesh:
        train_step = jax.jit(steps.make_train_step(
            cfg, mesh, rules, peak_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
            total_steps=args.steps))
        start = ckpt.latest_step() or 0
        if start:
            _, host = ckpt.restore()
            # re-shard the host checkpoint onto the live mesh (works across
            # re-meshes: the specs define placement, not the old topology)
            from repro.models import spec as pspec

            shardings = pspec.param_shardings(steps.state_specs(cfg), mesh,
                                              rules)
            state = jax.tree.map(
                lambda h, s: jax.device_put(h, s), host, shardings)
            print(f"resumed from step {start}")
        else:
            state = steps.init_state(cfg, args.seed)

        def on_metrics(step, m, dt):
            if step % 10 == 0:
                print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  "
                      f"lr {float(m['lr']):.2e}  {dt * 1e3:.0f} ms",
                      flush=True)

        state, step, fails = resilient_train(
            state=state, train_step=train_step, pipeline=pipe, ckpt=ckpt,
            total_steps=args.steps, start_step=start,
            ckpt_every=args.ckpt_every, straggler=StragglerWatch(),
            mesh=mesh, rules=rules, on_metrics=on_metrics)
    print(f"done: step={step} restarts={fails}")


if __name__ == "__main__":
    main()
