import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent.

Lowers + compiles every (architecture x input-shape) cell on the production
meshes — 16x16 (one pod, 256 chips) and 2x16x16 (two pods, 512 chips) — with
ShapeDtypeStruct inputs (zero allocation), and records memory_analysis,
cost_analysis, and the collective-bytes breakdown parsed from the HLO for
the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

NOTE: the XLA_FLAGS line above MUST run before any other import (jax locks
the device count on first init). Do not import this module from processes
that need the real device topology.
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax

from repro.configs import ASSIGNED, applicable_shapes, get
from repro.configs.base import SHAPES
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import rules_for

# TPU v5e constants (per chip) — roofline denominators
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the lowered HLO."""
    out: dict[str, int] = {}
    for m in re.finditer(
            r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", hlo_text, re.I):
        shapes, op = m.group(1), m.group(2).lower()
        nbytes = 0
        for dm in _SHAPE_RE.finditer(shapes):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[op] = out.get(op, 0) + nbytes
    return out


def _lower_one(cfg, shape, mesh, rules):
    """Lower + compile the right step for this shape kind."""
    if shape.kind == "train":
        state_structs, state_sh = steps.abstract_state(cfg, mesh, rules)
        fn = steps.make_train_step(cfg, mesh, rules)
        inputs = steps.input_specs(cfg, shape, mesh, rules)
        return jax.jit(fn, out_shardings=(state_sh, None)).lower(
            state_structs, inputs).compile()
    state_structs, _ = steps.abstract_state(cfg, mesh, rules)
    params_structs = state_structs["params"]
    inputs = steps.input_specs(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg, mesh, rules, cache_len=shape.seq_len)
        return jax.jit(fn).lower(params_structs, inputs).compile()
    fn = steps.make_decode_step(cfg, mesh, rules)
    return jax.jit(fn).lower(params_structs, inputs["tokens"],
                             inputs["caches"], inputs["pos"]).compile()


def _costs_of(compiled):
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": colls}


def _combine(base, slope, n):
    out = {"flops": base["flops"] + n * slope["flops"],
           "hbm_bytes": base["hbm_bytes"] + n * slope["hbm_bytes"],
           "collectives": {}}
    for k in set(base["collectives"]) | set(slope["collectives"]):
        out["collectives"][k] = base["collectives"].get(k, 0) \
            + n * slope["collectives"].get(k, 0)
    return out


def _diff(a, b):
    return {"flops": a["flops"] - b["flops"],
            "hbm_bytes": a["hbm_bytes"] - b["hbm_bytes"],
            "collectives": {k: a["collectives"].get(k, 0)
                            - b["collectives"].get(k, 0)
                            for k in set(a["collectives"])
                            | set(b["collectives"])}}


def probe_costs(cfg, shape, mesh, rules):
    """Exact per-op costs via unrolled small probes, extrapolated to depth.

    XLA's cost_analysis counts while-loop bodies once regardless of trip
    count, so the production (scanned) program under-reports. Probes set
    REPRO_UNROLL_SCAN=1 (every maybe_scan becomes a Python loop) on 1-2
    layer models, then costs extrapolate linearly in the layer count —
    exact because every per-layer term (fwd, bwd, optimizer, collectives)
    is linear in depth.
    """
    os.environ["REPRO_UNROLL_SCAN"] = "1"
    try:
        if cfg.is_encoder_decoder:
            f11 = _costs_of(_lower_one(cfg.replace(
                num_encoder_layers=1, num_layers=1), shape, mesh, rules))
            f21 = _costs_of(_lower_one(cfg.replace(
                num_encoder_layers=2, num_layers=1), shape, mesh, rules))
            f12 = _costs_of(_lower_one(cfg.replace(
                num_encoder_layers=1, num_layers=2), shape, mesh, rules))
            enc_slope, dec_slope = _diff(f21, f11), _diff(f12, f11)
            base = _diff(_diff(f11, enc_slope), dec_slope)
            total = _combine(_combine(base, enc_slope, cfg.num_encoder_layers),
                             dec_slope, cfg.num_layers)
            return total
        from repro.models.lm import segments

        segs = segments(cfg)
        pre = cfg.first_dense_layers
        body_len, n = len(segs[-1][0]), segs[-1][1]
        f0 = _costs_of(_lower_one(cfg.replace(num_layers=pre), shape, mesh,
                                  rules))
        f1 = _costs_of(_lower_one(cfg.replace(num_layers=pre + body_len),
                                  shape, mesh, rules))
        return _combine(f0, _diff(f1, f0), n)
    finally:
        os.environ.pop("REPRO_UNROLL_SCAN", None)


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE), D = tokens processed."""
    n = cfg.active_params() if cfg.num_experts else cfg.num_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               extra_cfg: dict | None = None, probe: bool = True):
    """Lower + compile one (arch, shape, mesh) cell; return the report."""
    cfg = get(arch)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh)
    chips = mesh.size

    t0 = time.time()
    with mesh:
        compiled = _lower_one(cfg, shape, mesh, rules)  # the production scan
        costs = probe_costs(cfg, shape, mesh, rules) if probe else \
            _costs_of(compiled)
    t1 = time.time()

    mem = compiled.memory_analysis()
    coll_total = sum(costs["collectives"].values())
    # per-device roofline terms (cost_analysis is per-partition under SPMD)
    terms = {"compute": costs["flops"] / PEAK_FLOPS,
             "memory": costs["hbm_bytes"] / HBM_BW,
             "collective": coll_total / ICI_BW}
    mf = model_flops(cfg, shape)
    hlo_flops_global = costs["flops"] * chips
    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(t1 - t0, 1),
        "per_device": {
            "flops": costs["flops"],
            "hbm_bytes": costs["hbm_bytes"],
            "collective_bytes": coll_total,
            "collectives": costs["collectives"],
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes_upper": getattr(mem, "temp_size_in_bytes", 0),
        },
        "roofline_s": terms,
        "bottleneck": max(terms, key=terms.get),
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_flops_global
                               if hlo_flops_global else None),
        "step_time_bound_s": max(terms.values()),
    }
    return report


def run_cells(cells, *, out_path=None, extra_cfg=None):
    results = []
    for arch, shape_name, multi_pod in cells:
        tag = f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
        try:
            rep = lower_cell(arch, shape_name, multi_pod=multi_pod,
                             extra_cfg=extra_cfg)
            b = rep["roofline_s"]
            hbm = (rep["per_device"]["peak_bytes"]
                   + rep["per_device"]["argument_bytes"]) / 2 ** 30
            print(f"PASS {tag}: compile={rep['compile_s']}s "
                  f"bottleneck={rep['bottleneck']} "
                  f"t=(c {b['compute']:.2e} | m {b['memory']:.2e} | "
                  f"x {b['collective']:.2e})s "
                  f"hbm={hbm:.2f}GiB "
                  f"useful={rep['useful_flops_ratio'] and round(rep['useful_flops_ratio'], 2)}",
                  flush=True)
            results.append(rep)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:400]}",
                  flush=True)
            results.append({"arch": arch, "shape": shape_name,
                            "mesh": "2x16x16" if multi_pod else "16x16",
                            "error": f"{type(e).__name__}: {str(e)[:2000]}"})
        if out_path:
            Path(out_path).write_text(json.dumps(results, indent=1))
    return results


def all_cells(multi_pod: bool | None = None):
    cells = []
    meshes = [False, True] if multi_pod is None else [multi_pod]
    for arch in ASSIGNED:
        cfg = get(arch)
        for shape in applicable_shapes(cfg):
            for mp in meshes:
                cells.append((arch, shape.name, mp))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.all:
        cells = all_cells(None if args.both_meshes else args.multi_pod)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in meshes]
    results = run_cells(cells, out_path=args.out)
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results) - n_fail}/{len(results)} cells passed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
