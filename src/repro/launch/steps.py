"""Step builders: train_step / prefill_step / decode_step per config.

These are the functions the dry-run lowers on the production meshes and the
train/serve drivers jit on real devices. All shardings come from the
logical-axis rules; abstract inputs come from ``input_specs`` /
``abstract_state`` so no full-size tensor is ever allocated off-cluster.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import encdec, lm, registry
from repro.models import spec as pspec
from repro.optim import schedule
from repro.sharding.rules import logical_sharding, rules_for


# ----------------------------------------------------------------------
# abstract inputs per (arch x shape)


def batch_struct(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32, dt = jnp.int32, jnp.dtype(cfg.dtype)
    ft = cfg.frontend_tokens if cfg.frontend != "none" else 0
    if shape.kind == "train" or shape.kind == "prefill":
        out = {}
        if cfg.is_encoder_decoder:
            out["tokens"] = ((B, S), i32, ("batch", "seq"))
            out["frames"] = ((B, cfg.encoder_seq, cfg.d_model), dt,
                             ("batch", None, None))
        elif cfg.frontend == "vlm" or cfg.frontend == "vit_stub":
            out["tokens"] = ((B, S - ft), i32, ("batch", "seq"))
            out["patch_embeds"] = ((B, ft, cfg.d_model), dt,
                                   ("batch", None, None))
        else:
            out["tokens"] = ((B, S), i32, ("batch", "seq"))
        if shape.kind == "train":
            out["labels"] = ((B, S), i32, ("batch", "seq"))
        return out
    # decode: one new token against a cache of length S
    return {"tokens": ((B, 1), i32, ("batch", None))}


def _to_structs(tree, mesh, rules):
    def leaf(v):
        shp, dt, ax = v
        sh = logical_sharding(ax, shp, rules, mesh) if mesh is not None else None
        return jax.ShapeDtypeStruct(shp, dt, sharding=sh)
    return jax.tree.map(leaf, tree,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh=None, rules=None):
    """Abstract (no-allocation) inputs for this cell, sharded for `mesh`."""
    rules = rules if rules is not None else (
        rules_for(cfg, mesh) if mesh is not None else None)
    specs = _to_structs(batch_struct(cfg, shape), mesh, rules)
    if shape.kind == "decode":
        cache = registry.cache_struct(cfg, shape.global_batch, shape.seq_len)
        specs["caches"] = _to_structs(cache, mesh, rules)
        specs["pos"] = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=(logical_sharding((), (), rules, mesh)
                                     if mesh is not None else None))
    return specs


# ----------------------------------------------------------------------
# train state


def state_specs(cfg: ArchConfig):
    params = registry.model_specs(cfg)
    opt = optim.get(cfg.optimizer).state_specs(params, cfg.opt_state_dtype)
    return {"params": params, "opt": opt}


def abstract_state(cfg, mesh, rules):
    specs = state_specs(cfg)
    structs = pspec.abstract_params(specs, cfg.param_dtype)
    shardings = pspec.param_shardings(specs, mesh, rules)
    return (jax.tree.map(lambda st, sh: jax.ShapeDtypeStruct(
        st.shape, st.dtype, sharding=sh), structs, shardings), shardings)


def init_state(cfg, seed=0):
    specs = state_specs(cfg)
    return pspec.init_params(specs, seed, cfg.param_dtype)


# ----------------------------------------------------------------------
# loss


def _ce_loss(logits, labels):
    """Sharded-vocab-safe cross entropy.

    No take_along_axis on the vocab axis (GSPMD would all-gather the full
    (B,S,V) logits): the gold logit comes from a one-hot contraction and the
    logsumexp from local reductions — both keep V sharded, reducing to tiny
    (B,S) tensors (one all-reduce each).
    """
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", onehot, logits,
                      preferred_element_type=jnp.float32)
    nll = lse - gold
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _forward_for(cfg):
    if cfg.is_encoder_decoder:
        def f(params, batch, mode, rules, mesh):
            return encdec.forward(params, cfg, batch["tokens"],
                                  batch.get("frames"), mode=mode,
                                  rules=rules, mesh=mesh)
        return f

    def f(params, batch, mode, rules, mesh):
        return lm.forward(params, cfg, batch["tokens"], mode=mode,
                          prefix_embeds=batch.get("patch_embeds"),
                          rules=rules, mesh=mesh)
    return f


# ----------------------------------------------------------------------
# steps


def make_train_step(cfg: ArchConfig, mesh=None, rules=None, *,
                    peak_lr=3e-4, warmup=100, total_steps=10_000,
                    clip_norm=1.0, accum: int = 1):
    fwd = _forward_for(cfg)
    opt_mod = optim.get(cfg.optimizer)
    compute_dt = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch):
        # cast the f32 master tree to the compute dtype ONCE, before any
        # use: otherwise every FSDP all-gather moves f32 over the wire and
        # casts after (measured 2x collective bytes on the 398B config —
        # EXPERIMENTS.md §Perf iter J1); the elementwise cast preserves
        # shardings, so gathers downstream are bf16.
        pc = jax.tree.map(
            lambda p: p.astype(compute_dt) if p.dtype == jnp.float32 else p,
            params)
        logits, _, aux = fwd(pc, batch, "train", rules, mesh)
        loss = _ce_loss(logits, batch["labels"])
        total = loss + cfg.router_aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        if accum == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g),
                        jax.tree.map(jnp.add, m_acc, m)), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            zero_m = {"loss": jnp.zeros((), jnp.float32),
                      "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(micro, (zero_g, zero_m), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)
        grads, gnorm = schedule.clip_by_global_norm(grads, clip_norm)
        # step+1: the schedule is evaluated for the step being taken (a
        # 0-indexed schedule would make the very first update a no-op)
        lr = schedule.warmup_cosine(opt_state["step"] + 1, peak_lr=peak_lr,
                                    warmup_steps=warmup,
                                    total_steps=total_steps)
        new_params, new_opt = opt_mod.update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh=None, rules=None, *,
                      cache_len: int = 0):
    fwd_ed = cfg.is_encoder_decoder

    def prefill_step(params, batch):
        if fwd_ed:
            logits, caches, _ = encdec.forward(
                params, cfg, batch["tokens"], batch.get("frames"),
                mode="prefill", cache_len=cache_len, rules=rules, mesh=mesh)
        else:
            logits, caches, _ = lm.forward(
                params, cfg, batch["tokens"], mode="prefill",
                prefix_embeds=batch.get("patch_embeds"),
                cache_len=cache_len, rules=rules, mesh=mesh)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None, rules=None):
    def decode_step(params, tokens, caches, pos):
        if cfg.is_encoder_decoder:
            logits, caches, _ = encdec.forward(
                params, cfg, tokens, None, mode="decode", caches=caches,
                pos=pos, rules=rules, mesh=mesh)
        else:
            logits, caches, _ = lm.forward(
                params, cfg, tokens, mode="decode", caches=caches, pos=pos,
                rules=rules, mesh=mesh)
        return logits, caches

    return decode_step
