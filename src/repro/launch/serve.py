"""Serving driver: batched prefill + decode loop with sharded KV cache."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get, tiny_variant
from repro.launch import steps
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.sharding.rules import rules_for


def generate(cfg, params, prompts, *, max_new: int, cache_len: int,
             mesh=None, rules=None, temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S) int32 -> (B, max_new) greedy/temperature samples."""
    prefill = jax.jit(steps.make_prefill_step(cfg, mesh, rules,
                                              cache_len=cache_len))
    decode = jax.jit(steps.make_decode_step(cfg, mesh, rules))
    B, S = prompts.shape
    logits, caches = prefill(params, {"tokens": prompts})
    key = jax.random.key(seed)
    outs = []
    tok = _sample(logits[:, -1], temperature, key, cfg)
    outs.append(tok)
    for i in range(max_new - 1):
        logits, caches = decode(params, tok[:, None], caches,
                                jnp.asarray(S + i, jnp.int32))
        key = jax.random.fold_in(key, i)
        tok = _sample(logits[:, 0], temperature, key, cfg)
        outs.append(tok)
    return jnp.stack(outs, axis=1)


def _sample(logits, temperature, key, cfg):
    logits = logits[:, : cfg.vocab_size]
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", choices=["local", "pod", "multipod"],
                    default="local")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    mesh = make_local_mesh() if args.mesh == "local" else \
        make_production_mesh(multi_pod=(args.mesh == "multipod"))
    rules = rules_for(cfg, mesh)

    with mesh:
        params = steps.init_state(cfg, 0)["params"]
        prompts = jax.random.randint(jax.random.key(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        t0 = time.perf_counter()
        out = generate(cfg, params, prompts,
                       max_new=args.max_new,
                       cache_len=args.prompt_len + args.max_new,
                       mesh=mesh, rules=rules)
        out.block_until_ready()
        dt = time.perf_counter() - t0
    total = args.batch * args.max_new
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample row:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
