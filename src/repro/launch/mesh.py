"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's 512-placeholder-device
trick to stay isolated from smoke tests/benches that must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests/benches)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
