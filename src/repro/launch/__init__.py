"""Launch layer: mesh construction, step builders, drivers.

NOTE: do NOT import repro.launch.dryrun from here — it sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import time and
must only run as `python -m repro.launch.dryrun`.
"""
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: F401
