"""Pointwise (1x1) convolution — the other half of the MobileNet family.

A 1x1 conv is a single (pixels, C) @ (C, K) GEMM; the ILP-M mapping is the
degenerate one-tap case of `ilpm_conv`:

  * output channels K on the LANE dimension, K-tiled grid;
  * the image tile is **VMEM-resident across the whole grid row** (its
    BlockSpec index map ignores the K axis) — expand/project pairs in
    inverted-residual blocks reread the same activations, so residency is
    where the traffic win is;
  * one MXU contraction per grid step, no halo and no padding (R=S=1).

Kept separate from `ilpm` so the tuner can cost it without tap-loop
overheads and so dispatch can skip SAME padding entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, H, W):
    """x_ref: (1, H, W, C) — full image, VMEM-pinned.
    w_ref: (1, 1, C, TK) — one output-channel slab.
    o_ref: (1, H, W, TK).
    """
    C = x_ref.shape[-1]
    TK = w_ref.shape[-1]
    xs = x_ref[0].reshape(H * W, C)
    acc = jnp.dot(xs, w_ref[0, 0], preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(H, W, TK).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def pointwise_conv(x, w, *, block_k: int = 128, interpret: bool = False):
    """x: (B, H, W, C) — no padding needed; w: (1,1,C,K) -> (B, H, W, K)."""
    B, H, W, C = x.shape
    R, S, _, K = w.shape
    assert (R, S) == (1, 1), f"pointwise kernel wants 1x1 filters, got {w.shape}"
    tk = min(block_k, K)
    grid = (B, pl.cdiv(K, tk))
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W),
        grid=grid,
        in_specs=[
            # index map ignores k -> image stays resident across the K row
            pl.BlockSpec((1, H, W, C), lambda b, k: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, C, tk), lambda b, k: (0, 0, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, H, W, tk), lambda b, k: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x.dtype),
        interpret=interpret,
    )(x, w)
