"""Pointwise (1x1) convolution — the other half of the MobileNet family.

A 1x1 conv is a single (pixels, C) @ (C, K) GEMM; the ILP-M mapping is the
degenerate one-tap case of `ilpm_conv`:

  * output channels K on the LANE dimension, K-tiled grid;
  * the image tile is **VMEM-resident across the whole grid row** (its
    BlockSpec index map ignores the K axis) — expand/project pairs in
    inverted-residual blocks reread the same activations, so residency is
    where the traffic win is;
  * one MXU contraction per grid step, no halo and no padding (R=S=1);
  * stride ∈ {1, 2}: strided 1x1 convs (ResNet projection shortcuts at
    stage entries) subsample the resident image in-kernel — `x[::2, ::2]`
    against the pinned tile, no XLA gather pass;
  * optional fused (scale, bias, act) epilogue in the output write, same
    contract as `ilpm_conv`.

Kept separate from `ilpm` so the tuner can cost it without tap-loop
overheads and so dispatch can skip SAME padding entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fusion import epilogue_operands
from repro.kernels.ref import apply_act


def _kernel(x_ref, w_ref, *refs, H, W, stride, act, fused):
    """x_ref: (1, Hin, Win, C) — full image, VMEM-pinned.
    w_ref: (1, 1, C, TK) — one output-channel slab.
    refs: optional (scale, bias) (1, TK) slabs, then o_ref (1, H, W, TK).
    """
    o_ref = refs[-1]
    C = x_ref.shape[-1]
    TK = w_ref.shape[-1]
    xs = x_ref[0, ::stride, ::stride, :].reshape(H * W, C)
    acc = jnp.dot(xs, w_ref[0, 0], preferred_element_type=jnp.float32)
    if fused:
        acc = acc * refs[0][0] + refs[1][0]
    acc = apply_act(acc, act)
    o_ref[0] = acc.reshape(H, W, TK).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "block_k", "act", "interpret"))
def pointwise_conv(x, w, *, stride: int = 1, block_k: int = 128,
                   scale=None, bias=None, act=None, interpret: bool = False):
    """x: (B, H, W, C) — no padding needed; w: (1,1,C,K)
    -> (B, ceil(H/stride), ceil(W/stride), K)."""
    B, H, W, C = x.shape
    R, S, _, K = w.shape
    assert (R, S) == (1, 1), f"pointwise kernel wants 1x1 filters, got {w.shape}"
    Ho = -(-H // stride)
    Wo = -(-W // stride)
    tk = min(block_k, K)
    grid = (B, pl.cdiv(K, tk))
    operands = [x, w]
    in_specs = [
        # index map ignores k -> image stays resident across the K row
        pl.BlockSpec((1, H, W, C), lambda b, k: (b, 0, 0, 0)),
        pl.BlockSpec((1, 1, C, tk), lambda b, k: (0, 0, 0, k)),
    ]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, tk, lambda b, k: (0, k))
    operands += extra
    in_specs += extra_specs
    return pl.pallas_call(
        functools.partial(_kernel, H=Ho, W=Wo, stride=stride, act=act,
                          fused=fused),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Ho, Wo, tk), lambda b, k: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, K), x.dtype),
        interpret=interpret,
    )(*operands)
