"""Fused-block megakernels — the per-block speed tier above per-conv.

The paper's lesson is that single-image inference is memory-bound, so the
win is cutting HBM round-trips. The per-conv kernels (ilpm/depthwise/
pointwise) already keep each layer's image VMEM-resident; these kernels
keep the *intermediates between layers* resident too:

  * ``fused_inverted_residual`` — MobileNet's expand(1x1) -> depthwise
    (RxS, stride 1|2) -> project(1x1) chain in ONE ``pallas_call``. The
    expanded tensor (t*Cin wide — the largest activation in the network)
    is computed, SAME-padded, convolved, and consumed entirely in VMEM;
    it never touches HBM ("High Performance Depthwise and Pointwise
    Convolutions on Mobile Devices" builds its mobile speedup on exactly
    this fusion). The expanded width is cut into per-channel slabs
    (``block_m``): the grid walks (batch, mid-slab), each step expands one
    slab, depthwise-convolves it, and accumulates its partial projection
    into an fp32 VMEM scratch; the last slab applies the project BN
    epilogue and — when ``residual`` (stride 1, Cin == Cout) — folds the
    identity add into the single output write, reusing the already-
    resident input (the shortcut costs zero extra HBM traffic).
  * ``fused_residual_conv`` — the second conv of a ResNet basic/
    bottleneck block (ilpm-style tap loop, K on lanes) with the shortcut
    add and the outer ReLU folded into the output write: per-layer this
    costs a full extra read-modify-write pass over the conv output.

Numerics mirror the per-layer chain stage for stage — fp32 accumulate,
each stage's BN/act epilogue in fp32, cast to the compute dtype exactly
where the per-layer kernel's output write casts — so at fp32 the fused
block with a single mid slab is *bitwise* equal to the per-layer path
(the project contraction is split only when ``block_m < mid``, which
reorders the reduction; ``block_m`` defaults large so single-slab wins
whenever it fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import apply_act


def _vec(v, n):
    """Materialize an optional (n,) epilogue vector as a (1, n) fp32 row
    (ones for a missing scale, zeros for a missing bias are handled by the
    caller passing None through ``_vec_or``)."""
    return v.astype(jnp.float32).reshape(1, n)


def _vec_or(v, n, fill):
    if v is None:
        return (jnp.ones((1, n), jnp.float32) if fill == 1.0
                else jnp.zeros((1, n), jnp.float32))
    return _vec(v, n)


# ----------------------------------------------------------------------
# inverted residual: expand -> depthwise -> project, expanded tensor in VMEM


def _ir_kernel(x_ref, *refs, H, W, OH, OW, R, S, stride, pads, act, out_act,
               residual, expanded, nm, compute_dtype):
    """One grid step = one expanded-channel slab of one image.

    x_ref: (1, H, W, Cin) — the *unpadded* input, VMEM-resident across the
    whole mid-slab row (its index map ignores the m axis); also the
    residual identity. Then, when ``expanded``: w1 (1,1,Cin,TM), s1/b1
    (1,TM); always: wdw (R,S,1,TM), sdw/bdw (1,TM), w2 (1,1,TM,Cout),
    s2/b2 (1,Cout), o_ref (1,OH,OW,Cout), and the fp32 (OH*OW, Cout)
    projection accumulator scratch.
    """
    acc_ref = refs[-1]
    o_ref = refs[-2]
    if expanded:
        w1, s1, b1, wdw, sdw, bdw, w2, s2, b2 = refs[:9]
    else:
        wdw, sdw, bdw, w2, s2, b2 = refs[:6]
    m = pl.program_id(1)
    x = x_ref[0]
    # --- expand: one (H*W, Cin) @ (Cin, TM) MXU step + BN/act epilogue,
    # cast to the compute dtype exactly where the per-layer pointwise
    # kernel's output write would cast ---
    if expanded:
        e = jnp.dot(x.reshape(H * W, x.shape[-1]), w1[0, 0],
                    preferred_element_type=jnp.float32)
        e = apply_act(e * s1[0] + b1[0], act).astype(compute_dtype)
        e = e.reshape(H, W, e.shape[-1])
    else:
        e = x  # t == 1: the slab *is* the input (tm == mid == cin)
    # --- SAME-pad the slab in VMEM: exact zeros, identical to the
    # per-layer pad_same of the expand output (the expanded tensor's HBM
    # round-trip this kernel exists to delete) ---
    (ph_lo, ph_hi), (pw_lo, pw_hi) = pads
    ep = jnp.pad(e, ((ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    # --- depthwise: static tap loop over the resident padded slab, VPU
    # work, fp32 accumulate, BN/act epilogue, cast-on-"write" (to VMEM) ---
    d = jnp.zeros((OH, OW, ep.shape[-1]), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = ep[r:r + (OH - 1) * stride + 1:stride,
                    s:s + (OW - 1) * stride + 1:stride, :]
            d += xs.astype(jnp.float32) * wdw[r, s, 0].astype(jnp.float32)
    d = apply_act(d * sdw[0] + bdw[0], act).astype(compute_dtype)
    # --- project: this slab's partial (OH*OW, Cout) contraction ---
    part = jnp.dot(d.reshape(OH * OW, d.shape[-1]), w2[0, 0],
                   preferred_element_type=jnp.float32)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(m > 0)
    def _accumulate():
        acc_ref[...] += part

    # --- last slab: project epilogue + residual fold + the single write ---
    @pl.when(m == nm - 1)
    def _write():
        y = acc_ref[...] * s2[0] + b2[0]
        y = apply_act(y, out_act).astype(o_ref.dtype)
        if residual:
            # the identity is the already-resident input: zero extra HBM
            y = y + x.reshape(y.shape)
        o_ref[0] = y.reshape(OH, OW, y.shape[-1])


@functools.partial(jax.jit, static_argnames=("stride", "block_m", "act",
                                             "out_act", "residual",
                                             "interpret"))
def fused_inverted_residual(x, weights, *, stride: int = 1,
                            block_m: int = 512, residual: bool = False,
                            act: str | None = "relu6",
                            out_act: str | None = None,
                            interpret: bool = False):
    """x: (B, H, W, Cin) *unpadded*; weights: a dict with

      * ``w1`` (1, 1, Cin, mid) + ``s1``/``b1`` (mid,) — the expansion
        conv and its folded BN (omit all three for t == 1 blocks);
      * ``wdw`` (R, S, 1, mid) + ``sdw``/``bdw`` (mid,) — depthwise;
      * ``w2`` (1, 1, mid, Cout) + ``s2``/``b2`` (Cout,) — projection
        (linear: ``out_act`` stays None in MobileNetV2).

    -> (B, ceil(H/stride), ceil(W/stride), Cout). ``block_m`` tiles the
    expanded width (the tuned parameter); slabs must divide ``mid``
    exactly — a non-dividing ``block_m`` falls back to the single-slab
    variant (a ragged mid slab would double-count the projection's
    cross-slab accumulation). ``residual`` folds ``+ x`` into the output
    write (caller guarantees stride == 1 and Cin == Cout).
    """
    B, H, W, Cin = x.shape
    w1 = weights.get("w1")
    expanded = w1 is not None
    wdw, w2 = weights["wdw"], weights["w2"]
    R, S, _, mid = wdw.shape
    Cout = w2.shape[-1]
    assert w2.shape[:3] == (1, 1, mid), w2.shape
    assert not expanded or w1.shape == (1, 1, Cin, mid), (w1.shape, mid)
    assert expanded or mid == Cin, (mid, Cin)
    assert not residual or (stride == 1 and Cin == Cout)
    OH = -(-H // stride)
    OW = -(-W // stride)
    ph = max((OH - 1) * stride + R - H, 0)
    pw = max((OW - 1) * stride + S - W, 0)
    pads = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    tm = min(block_m, mid)
    if not expanded or mid % tm:
        tm = mid  # single slab: t == 1 slabs ride the unsliced input
    nm = mid // tm
    grid = (B, nm)
    operands = [x]
    in_specs = [
        # index map ignores m -> the input (and residual identity) stays
        # resident across the whole slab row
        pl.BlockSpec((1, H, W, Cin), lambda b, m: (b, 0, 0, 0)),
    ]
    row = pl.BlockSpec((1, tm), lambda b, m: (0, m))
    if expanded:
        operands += [w1, _vec_or(weights.get("s1"), mid, 1.0),
                     _vec_or(weights.get("b1"), mid, 0.0)]
        in_specs += [pl.BlockSpec((1, 1, Cin, tm), lambda b, m: (0, 0, 0, m)),
                     row, row]
    operands += [wdw, _vec_or(weights.get("sdw"), mid, 1.0),
                 _vec_or(weights.get("bdw"), mid, 0.0),
                 w2, _vec_or(weights.get("s2"), Cout, 1.0),
                 _vec_or(weights.get("b2"), Cout, 0.0)]
    full = pl.BlockSpec((1, Cout), lambda b, m: (0, 0))
    in_specs += [pl.BlockSpec((R, S, 1, tm), lambda b, m: (0, 0, 0, m)),
                 row, row,
                 pl.BlockSpec((1, 1, tm, Cout), lambda b, m: (0, 0, m, 0)),
                 full, full]
    return pl.pallas_call(
        functools.partial(_ir_kernel, H=H, W=W, OH=OH, OW=OW, R=R, S=S,
                          stride=stride, pads=pads, act=act, out_act=out_act,
                          residual=residual, expanded=expanded, nm=nm,
                          compute_dtype=x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, OH, OW, Cout), lambda b, m: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, OH, OW, Cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((OH * OW, Cout), jnp.float32)],
        interpret=interpret,
    )(*operands)


# ----------------------------------------------------------------------
# residual conv: the ResNet block tail with the shortcut add fused


def _rc_kernel(x_ref, w_ref, s_ref, b_ref, res_ref, o_ref, *, H, W, R, S,
               act):
    """ilpm-style tap loop (image resident, K on lanes) plus a residual
    operand slab; the shortcut add and the block's outer activation fold
    into the single output write."""
    C = x_ref.shape[-1]
    TK = w_ref.shape[-1]
    acc = jnp.zeros((H * W, TK), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = x_ref[0, r:r + H, s:s + W, :].reshape(H * W, C)
            acc += jnp.dot(xs, w_ref[r, s],
                           preferred_element_type=jnp.float32)
    # the conv's own folded-BN write (cast where the per-layer kernel
    # casts), then the shortcut add + outer act in the compute dtype —
    # the exact op order of the unfused `act(conv(x) + identity)`
    y = (acc * s_ref[0] + b_ref[0]).astype(o_ref.dtype)
    y = apply_act(y + res_ref[0].reshape(H * W, TK), act)
    o_ref[0] = y.reshape(H, W, TK)


@functools.partial(jax.jit, static_argnames=("block_k", "act", "interpret"))
def fused_residual_conv(x_padded, weights, *, res, block_k: int = 128,
                        act: str | None = "relu", interpret: bool = False):
    """x_padded: (B, H+R-1, W+S-1, C) pre-padded (stride 1 only — every
    ResNet block's *second* conv is stride 1); weights: ``w`` (R, S, C, K)
    + ``scale``/``bias`` (K,); ``res``: the (B, H, W, K) shortcut branch
    (identity or projection output) -> (B, H, W, K).

    Equivalent to ``act(conv(x)*scale + bias + res)`` with the add and
    activation fused into the conv's output write: the unfused chain pays
    an extra read-modify-write pass over the conv output.
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = weights["w"].shape
    H, W = Hp - R + 1, Wp - S + 1
    assert res.shape == (B, H, W, K), (res.shape, (B, H, W, K))
    tk = min(block_k, K)
    grid = (B, pl.cdiv(K, tk))
    operands = [x_padded, weights["w"],
                _vec_or(weights.get("scale"), K, 1.0),
                _vec_or(weights.get("bias"), K, 0.0), res]
    row = pl.BlockSpec((1, tk), lambda b, k: (0, k))
    in_specs = [
        pl.BlockSpec((1, Hp, Wp, C), lambda b, k: (b, 0, 0, 0)),
        pl.BlockSpec((R, S, C, tk), lambda b, k: (0, 0, 0, k)),
        row, row,
        pl.BlockSpec((1, H, W, tk), lambda b, k: (b, 0, 0, k)),
    ]
    return pl.pallas_call(
        functools.partial(_rc_kernel, H=H, W=W, R=R, S=S, act=act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, W, tk), lambda b, k: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x_padded.dtype),
        interpret=interpret,
    )(*operands)
