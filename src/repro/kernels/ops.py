"""jit'd public wrappers over the Pallas kernels, with dispatch.

TPU is the TARGET; this container is CPU-only. Policy:
  * ``impl='pallas'`` runs the Pallas kernels (interpret=True off-TPU) —
    used by the kernel tests/benchmarks;
  * ``impl='jnp'`` runs the structural jnp references — used inside model
    forward passes so the 512-device dry-run lowers plain XLA HLO;
  * ``impl='auto'`` picks pallas on TPU, jnp elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import causal_conv1d as _cc
from repro.kernels import depthwise_conv as _dw
from repro.kernels import fused_block as _fb
from repro.kernels import direct_conv as _dc
from repro.kernels import ilpm_conv as _il
from repro.kernels import im2col_conv as _im
from repro.kernels import libdnn_conv as _lib
from repro.kernels import pointwise_conv as _pw
from repro.kernels import winograd_conv as _wg
from repro.kernels.gemm import gemm  # noqa: F401  (public)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(impl: str) -> bool:
    if impl == "auto":
        return _on_tpu()
    return impl == "pallas"


def _interp() -> bool:
    return not _on_tpu()


# ---- the conv algorithms (pre-padded inputs) -------------------------
#
# Shared epilogue contract: every wrapper takes optional ``scale``/``bias``
# ((K,) folded-BN vectors) and ``act`` ('relu' | 'relu6' | None). On the
# pallas path they are fused into the kernel's output write; on the jnp
# path ``ref.apply_epilogue`` applies the identical math as XLA ops, so the
# two impls stay numerically interchangeable. ``stride`` is call-site
# geometry (only the kernels that support it declare it).

def ilpm(x_padded, w, *, impl="auto", stride=1, block_k=128, scale=None,
         bias=None, act=None):
    if _use_pallas(impl):
        return _il.ilpm_conv(x_padded, w, stride=stride, block_k=block_k,
                             scale=scale, bias=bias, act=act,
                             interpret=_interp())
    return ref.apply_epilogue(ref.ilpm_conv(x_padded, w, stride=stride),
                              scale=scale, bias=bias, act=act)


def direct(x_padded, w, *, impl="auto", stride=1, block_h=8, scale=None,
           bias=None, act=None):
    if _use_pallas(impl):
        return _dc.direct_conv(x_padded, w, stride=stride, block_h=block_h,
                               scale=scale, bias=bias, act=act,
                               interpret=_interp())
    return ref.apply_epilogue(ref.direct_conv(x_padded, w, stride=stride),
                              scale=scale, bias=bias, act=act)


def im2col(x_padded, w, *, impl="auto", scale=None, bias=None, act=None):
    if _use_pallas(impl):
        return _im.im2col_conv(x_padded, w, scale=scale, bias=bias, act=act,
                               interpret=_interp())
    return ref.apply_epilogue(ref.im2col_conv(x_padded, w),
                              scale=scale, bias=bias, act=act)


def libdnn(x_padded, w, *, impl="auto", block_k=128, scale=None, bias=None,
           act=None):
    if _use_pallas(impl):
        return _lib.libdnn_conv(x_padded, w, block_k=block_k, scale=scale,
                                bias=bias, act=act, interpret=_interp())
    return ref.apply_epilogue(ref.libdnn_conv(x_padded, w),
                              scale=scale, bias=bias, act=act)


def winograd(x_padded, w, *, impl="auto", u=None, scale=None, bias=None,
             act=None):
    """``u`` is the cached filter transform U = G g Gᵀ (frozen weights:
    the engine computes it once per plan build)."""
    if _use_pallas(impl):
        return _wg.winograd_conv(x_padded, w, u=u, scale=scale, bias=bias,
                                 act=act, interpret=_interp())
    return ref.apply_epilogue(ref.winograd_conv(x_padded, w, u=u),
                              scale=scale, bias=bias, act=act)


# ---- the grouped family (MobileNet depthwise/pointwise) --------------

def depthwise(x_padded, w, *, impl="auto", stride=1, block_c=128, scale=None,
              bias=None, act=None):
    """Depthwise conv: x (B,Hp,Wp,C) pre-padded, w (R,S,1,M·C)
    -> (B,H,W,M·C).

    ``stride`` is geometry, not a tuned parameter — it comes from the call
    site, while ``block_c`` comes from the tuner. Stride 1 and 2 run
    in-kernel (MobileNet downsamples inside depthwise layers); channel
    multipliers M > 1 repeat the input slab on lanes in-kernel.
    """
    if _use_pallas(impl):
        return _dw.depthwise_conv(x_padded, w, stride=stride,
                                  block_c=block_c, scale=scale, bias=bias,
                                  act=act, interpret=_interp())
    return ref.apply_epilogue(ref.depthwise_conv(x_padded, w, stride=stride),
                              scale=scale, bias=bias, act=act)


def pointwise(x, w, *, impl="auto", stride=1, block_k=128, scale=None,
              bias=None, act=None):
    """1x1 conv: x (B,H,W,C) *unpadded*, w (1,1,C,K) -> (B,H',W',K)."""
    if _use_pallas(impl):
        return _pw.pointwise_conv(x, w, stride=stride, block_k=block_k,
                                  scale=scale, bias=bias, act=act,
                                  interpret=_interp())
    return ref.apply_epilogue(ref.pointwise_conv(x, w, stride=stride),
                              scale=scale, bias=bias, act=act)


# ---- fused blocks (per-BLOCK kernels, not per-conv) ------------------
#
# Registered in their own BLOCK_ALGORITHMS table and dispatched through
# ``dispatch_block``: block kernels take a *weights dict* (one entry per
# fused stage) where the per-conv table takes a single filter tensor, so
# sharing ``ALGORITHMS`` would break every caller that iterates it with
# ``dispatch(algo, x, w)`` (the precision sweep, the spy fixtures).

def fused_inverted_residual(x, weights, *, impl="auto", stride=1,
                            block_m=512, residual=False, act="relu6",
                            out_act=None):
    """MobileNet expand->depthwise->project in one kernel launch.

    ``x`` (B,H,W,Cin) *unpadded*; ``weights`` a dict: optional
    ``w1``/``s1``/``b1`` (expansion conv + folded BN — absent for t == 1
    blocks), ``wdw``/``sdw``/``bdw`` (depthwise), ``w2``/``s2``/``b2``
    (projection, linear). ``block_m`` tiles the expanded width (the tuned
    parameter); ``residual`` folds the identity add into the project
    write (stride 1, Cin == Cout only).
    """
    if _use_pallas(impl):
        return _fb.fused_inverted_residual(
            x, weights, stride=stride, block_m=block_m, residual=residual,
            act=act, out_act=out_act, interpret=_interp())
    return ref.fused_inverted_residual(x, weights, stride=stride,
                                       residual=residual, act=act,
                                       out_act=out_act)


def fused_residual_conv(x_padded, weights, *, impl="auto", res,
                        block_k=128, act="relu"):
    """ResNet block tail: the second conv with the shortcut add and outer
    ReLU fused into its output write. ``x_padded`` SAME-padded (stride 1);
    ``weights``: ``w``/``scale``/``bias``; ``res`` the shortcut branch."""
    if _use_pallas(impl):
        return _fb.fused_residual_conv(x_padded, weights, res=res,
                                       block_k=block_k, act=act,
                                       interpret=_interp())
    return ref.fused_residual_conv(x_padded, weights, res=res, act=act)


ALGORITHMS = {"ilpm": ilpm, "direct": direct, "im2col": im2col,
              "libdnn": libdnn, "winograd": winograd,
              "depthwise": depthwise, "pointwise": pointwise}

BLOCK_ALGORITHMS = {"fused_inverted_residual": fused_inverted_residual,
                    "fused_residual_conv": fused_residual_conv}

# the paper's five contenders — interchangeable on any dense 3x3 conv;
# the grouped family (depthwise/pointwise) has its own filter shapes
DENSE_ALGORITHMS = ("ilpm", "direct", "im2col", "libdnn", "winograd")


def kernel_params(algorithm: str, params: dict) -> dict:
    """Keep only the params this algorithm's wrapper accepts.

    The filter is what lets callers pass a superset of parameters — a
    tuned ``block_k`` plus call-site geometry like ``stride`` — to any
    algorithm: each wrapper receives exactly the keywords in its
    signature and the rest are dropped silently. A wrapper declaring
    ``**kwargs`` opts out of filtering and receives everything (the test
    suite's spy wrappers rely on this).
    """
    import inspect

    accepted = inspect.signature(ALGORITHMS[algorithm]).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD
           for p in accepted.values()):
        return dict(params)
    return {k: v for k, v in params.items() if k in accepted}


def dispatch(algorithm: str, x_padded, w, *, impl="auto", **params):
    """Run one algorithm by name with its tuned kernel parameters.

    This is the single funnel every planned conv site goes through: the
    engine's jitted forward calls it with the layer's tuned algorithm
    name and ``Choice.params``. Semantics:

      * ``ALGORITHMS`` is looked up at *call time*, so tests can spy on
        (or stub out) entries after import;
      * ``params`` are filtered per-algorithm by ``kernel_params`` — a
        plan tuned for one algorithm stays usable if dispatch falls back
        to another whose kernel takes different knobs. The same filter
        carries call-site geometry (``stride``), the fused epilogue
        (``scale``/``bias``/``act`` — every conv wrapper accepts these)
        and the cached Winograd transform (``u`` — winograd only, dropped
        elsewhere);
      * ``impl`` selects pallas vs jnp per the module policy above; the
        algorithm itself never changes with ``impl``, only its backend.

    ``x_padded`` must already carry the algorithm's expected padding
    (``pointwise`` takes the raw image; everything else takes SAME-padded
    input — ``repro.core.algorithms.conv2d`` handles this).
    """
    fn = ALGORITHMS[algorithm]
    return fn(x_padded, w, impl=impl, **kernel_params(algorithm, params))


def block_kernel_params(algorithm: str, params: dict) -> dict:
    """``kernel_params`` for the block-level table (same signature-filter
    rule, so spy wrappers declaring ``**kwargs`` opt out identically)."""
    import inspect

    accepted = inspect.signature(BLOCK_ALGORITHMS[algorithm]).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD
           for p in accepted.values()):
        return dict(params)
    return {k: v for k, v in params.items() if k in accepted}


def dispatch_block(algorithm: str, x, weights, *, impl="auto", **params):
    """Block-level twin of ``dispatch``: one call = one fused block.

    The engine's jitted forward funnels every *block* site the plan chose
    to fuse through here (per-conv sites keep going through ``dispatch``).
    ``weights`` is the block's stage dict, ``params`` carries the tuned
    knob (``block_m``/``block_k``) plus call-site geometry
    (``stride``/``residual``/``res``/``act``/``out_act``), filtered per
    algorithm exactly like the per-conv funnel. ``BLOCK_ALGORITHMS`` is
    looked up at call time so the dispatch-spy fixtures can wrap it.
    """
    fn = BLOCK_ALGORITHMS[algorithm]
    return fn(x, weights, impl=impl, **block_kernel_params(algorithm, params))


# ---- 1D ops used by the model substrate ------------------------------

def causal_conv1d(x, w, b=None, *, impl="auto", block_l=512):
    """Depthwise causal conv (Mamba stem): ILP-M technique in 1D."""
    if _use_pallas(impl):
        return _cc.causal_conv1d(x, w, b, block_l=block_l, interpret=_interp())
    return ref.causal_conv1d(x, w, b)


def conv1d_dense(x, w, b=None, *, stride=1):
    return ref.conv1d_dense(x, w, b, stride=stride)
