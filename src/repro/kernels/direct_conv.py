"""Direct convolution — the paper's strongest existing baseline (§3.3).

Pixel-major mapping (the paper's CONV_CACHE_FILTER structure): the grid
walks pixel tiles; the **entire filter bank** (R,S,C,K) is the VMEM-resident
operand (its index map ignores the pixel axis), and each grid step computes
all K channels for its pixel rows. On a GPU this layout forces the
shared-memory barrier per input channel; on TPU the analogous cost is VMEM
pressure — the filter residency is R·S·C·K (2.4 MB at conv4.x, 9.4 MB at
conv5.x) versus ILP-M's image residency (≤0.9 MB), which is what caps the
achievable pixel-tile depth. The benchmarks expose this in the VMEM columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, TH, W, R, S):
    """x_ref: (1, 1, TH+R-1, W+S-1, C) pixel row-band; w_ref: full
    (R,S,C,K); o_ref: (1, 1, TH, W, K)."""
    C = x_ref.shape[-1]
    K = w_ref.shape[-1]
    acc = jnp.zeros((TH * W, K), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = x_ref[0, 0, r:r + TH, s:s + W, :].reshape(TH * W, C)
            acc += jnp.dot(xs, w_ref[r, s],
                           preferred_element_type=jnp.float32)
    o_ref[0, 0] = acc.reshape(TH, W, K).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def direct_conv(x_padded, w, *, block_h: int = 8, interpret: bool = False):
    """x_padded: (B, H+R-1, W+S-1, C); w: (R,S,C,K) -> (B,H,W,K).

    Row-band pixel tiles of `block_h` rows; bands overlap by the R-1 halo,
    expressed as an element-offset index map on a (TH+R-1)-row block.
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H, W = Hp - R + 1, Wp - S + 1
    th = min(block_h, H)
    nh = pl.cdiv(H, th)
    grid = (B, nh)

    # Halo trick: pass a band of th+R-1 rows starting at row th*i. Block
    # starts must be multiples of the block shape in Pallas's Blocked mode,
    # so instead we pre-slice x into overlapping bands outside the kernel.
    bands = []
    for i in range(nh):
        lo = min(th * i, Hp - (th + R - 1))
        bands.append(jax.lax.dynamic_slice_in_dim(x_padded, lo, th + R - 1, 1))
    xb = jnp.stack(bands, axis=1)  # (B, nh, th+R-1, Wp, C)

    out = pl.pallas_call(
        functools.partial(_kernel, TH=th, W=W, R=R, S=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, th + R - 1, Wp, C), lambda b, i: (b, i, 0, 0, 0)),
            # filter bank resident: index map ignores the pixel axis
            pl.BlockSpec((R, S, C, K), lambda b, i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, th, W, K), lambda b, i: (b, i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, th, W, K), x_padded.dtype),
        interpret=interpret,
    )(xb, w)
    if nh * th == H:
        return out.reshape(B, H, W, K)
    # last band was clamped to start at H-th: drop its duplicated head rows
    main = out[:, :nh - 1].reshape(B, th * (nh - 1), W, K)
    tail = out[:, nh - 1, th * nh - H:]
    return jnp.concatenate([main, tail], axis=1)
