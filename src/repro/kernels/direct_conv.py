"""Direct convolution — the paper's strongest existing baseline (§3.3).

Pixel-major mapping (the paper's CONV_CACHE_FILTER structure): the grid
walks pixel tiles; the **entire filter bank** (R,S,C,K) is the VMEM-resident
operand (its index map ignores the pixel axis), and each grid step computes
all K channels for its pixel rows. On a GPU this layout forces the
shared-memory barrier per input channel; on TPU the analogous cost is VMEM
pressure — the filter residency is R·S·C·K (2.4 MB at conv4.x, 9.4 MB at
conv5.x) versus ILP-M's image residency (≤0.9 MB), which is what caps the
achievable pixel-tile depth. The benchmarks expose this in the VMEM columns.

Stride ∈ {1, 2} runs in-kernel (strided tap slices over each row band), and
an optional (scale, bias, act) epilogue folds BN + activation into the
output write — same contract as `ilpm_conv`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fusion import epilogue_operands
from repro.kernels.ref import apply_act


def _kernel(x_ref, w_ref, *refs, TH, W, R, S, stride, act, fused):
    """x_ref: (1, 1, (TH-1)*stride+R, Wp, C) pixel row-band; w_ref: full
    (R,S,C,K); refs: optional (scale, bias) (1, K), then o_ref
    (1, 1, TH, W, K)."""
    o_ref = refs[-1]
    C = x_ref.shape[-1]
    K = w_ref.shape[-1]
    acc = jnp.zeros((TH * W, K), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = x_ref[0, 0, r:r + (TH - 1) * stride + 1:stride,
                       s:s + (W - 1) * stride + 1:stride, :].reshape(
                           TH * W, C)
            acc += jnp.dot(xs, w_ref[r, s],
                           preferred_element_type=jnp.float32)
    if fused:
        acc = acc * refs[0][0] + refs[1][0]
    acc = apply_act(acc, act)
    o_ref[0, 0] = acc.reshape(TH, W, K).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "block_h", "act", "interpret"))
def direct_conv(x_padded, w, *, stride: int = 1, block_h: int = 8,
                scale=None, bias=None, act=None, interpret: bool = False):
    """x_padded: (B, (H-1)*stride+R, (W-1)*stride+S, C); w: (R,S,C,K)
    -> (B,H,W,K).

    Row-band pixel tiles of `block_h` output rows; bands overlap by the
    filter halo, expressed by pre-slicing x into overlapping bands outside
    the kernel (block starts must be multiples of the block shape in
    Pallas's Blocked mode).
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    th = min(block_h, H)
    nh = pl.cdiv(H, th)
    grid = (B, nh)

    # Halo trick: band i serves output rows [th*i, th*i+th) and needs input
    # rows starting at th*i*stride, (th-1)*stride + R of them. The last
    # band is clamped to end exactly at output row H.
    bh = (th - 1) * stride + R
    bands = []
    for i in range(nh):
        lo = min(th * i, H - th) * stride
        bands.append(jax.lax.dynamic_slice_in_dim(x_padded, lo, bh, 1))
    xb = jnp.stack(bands, axis=1)  # (B, nh, bh, Wp, C)

    operands = [xb, w]
    in_specs = [
        pl.BlockSpec((1, 1, bh, Wp, C), lambda b, i: (b, i, 0, 0, 0)),
        # filter bank resident: index map ignores the pixel axis
        pl.BlockSpec((R, S, C, K), lambda b, i: (0, 0, 0, 0)),
    ]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, K, lambda b, i: (0, 0))  # filter-resident: full K
    operands += extra
    in_specs += extra_specs
    out = pl.pallas_call(
        functools.partial(_kernel, TH=th, W=W, R=R, S=S, stride=stride,
                          act=act, fused=fused),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, th, W, K), lambda b, i: (b, i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, th, W, K), x_padded.dtype),
        interpret=interpret,
    )(*operands)
    if nh * th == H:
        return out.reshape(B, H, W, K)
    # last band was clamped to start at H-th: drop its duplicated head rows
    main = out[:, :nh - 1].reshape(B, th * (nh - 1), W, K)
    tail = out[:, nh - 1, th * nh - H:]
    return jnp.concatenate([main, tail], axis=1)
