"""im2col convolution — the paper's most-popular baseline (§3.1).

Deliberately two separate kernels with an HBM round-trip between them,
because that round-trip IS the algorithm's cost the paper measures
(Table 3: the unrolled matrix is kernel_size× the input, written by the
im2col kernel and read back by the GEMM kernel — 9.27 MB read at conv4.x
vs ILP-M's 2.46 MB). Phase 1 unrolls patches; phase 2 is the tiled GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gemm import gemm


def _unroll_kernel(x_ref, o_ref, *, H, W, R, S):
    """x_ref: (1, Hp, Wp, C) full image; o_ref: (1, H*W, R*S*C)."""
    C = x_ref.shape[-1]
    cols = []
    for r in range(R):
        for s in range(S):
            cols.append(x_ref[0, r:r + H, s:s + W, :].reshape(H * W, C))
    o_ref[0] = jnp.concatenate(cols, axis=-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r", "s", "interpret"))
def im2col_unroll(x_padded, *, r, s, interpret=False):
    B, Hp, Wp, C = x_padded.shape
    H, W = Hp - r + 1, Wp - s + 1
    return pl.pallas_call(
        functools.partial(_unroll_kernel, H=H, W=W, R=r, S=s),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, Hp, Wp, C), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, H * W, r * s * C), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H * W, r * s * C), x_padded.dtype),
        interpret=interpret,
    )(x_padded)


def im2col_conv(x_padded, w, *, scale=None, bias=None, act=None,
                interpret=False):
    """Two-phase im2col: unroll kernel -> HBM -> GEMM kernel.

    The (scale, bias, act) epilogue is applied as a separate pass after the
    GEMM — the two-phase structure has no single output-writing kernel to
    fold it into, which is part of why the cost model charges im2col extra
    traffic relative to the fused families.
    """
    from repro.kernels.ref import apply_epilogue

    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H, W = Hp - R + 1, Wp - S + 1
    patches = im2col_unroll(x_padded, r=R, s=S, interpret=interpret)
    out = jax.vmap(lambda p: gemm(p, w.reshape(R * S * C, K),
                                  interpret=interpret))(patches)
    return apply_epilogue(out.reshape(B, H, W, K), scale=scale, bias=bias,
                          act=act)
