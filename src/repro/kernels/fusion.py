"""Shared constructor for the kernels' fused-epilogue operands.

Every conv kernel that fuses the (scale, bias) folded-BN epilogue into its
output write appends the same operand tail to its ``pallas_call``: the two
(K,) vectors as (1, K) fp32 rows, block-sliced with the same K-slab index
map as the kernel's filter operand. This helper builds that tail once so
the contract can't drift between kernels.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl


def epilogue_operands(scale, bias, k, block, index_map):
    """-> (fused, extra_operands, extra_in_specs) for a pallas_call.

    When either of ``scale``/``bias`` is present both are materialized
    (ones/zeros default for the missing one) as (1, k) fp32 rows with a
    ``(1, block)`` BlockSpec indexed by ``index_map``; the kernel body
    reads them as ``refs[0][0]`` / ``refs[1][0]`` ((block,) vectors that
    broadcast over its accumulator). When neither is present the tail is
    empty and the kernel skips the epilogue multiply-add entirely.
    """
    fused = scale is not None or bias is not None
    if not fused:
        return False, [], []
    sc = jnp.ones(k, jnp.float32) if scale is None \
        else scale.astype(jnp.float32)
    bi = jnp.zeros(k, jnp.float32) if bias is None \
        else bias.astype(jnp.float32)
    spec = pl.BlockSpec((1, block), index_map)
    return True, [sc.reshape(1, k), bi.reshape(1, k)], [spec, spec]
