"""ILP-M convolution — the paper's contribution, as a Pallas TPU kernel.

TPU adaptation of the algorithm (DESIGN.md §2):
  * output channels K on the LANE dimension (the paper maps threads -> K);
  * the (padded) input image tile is **VMEM-resident across the whole grid
    row** — its BlockSpec index map ignores the K grid axis, so Pallas keeps
    it on-chip and never refetches it (the paper's shared-memory image tile,
    minus the barrier);
  * filters in HWIO ([R][S][C][K], K minor) — the paper's [C][R][S][K]
    coalesced layout, lane-aligned on TPU;
  * static tap loop: each (r, s) step is one `(H·W, C) @ (C, K_blk)` MXU
    contraction — one weight slab amortized over every pixel of the tile,
    the `workgroup_size : 1` arithmetic:load ratio of the paper.

Single-image (B small) is the design premise, exactly as in the paper: the
pixel axis, not the batch axis, feeds the sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, H, W, R, S):
    """x_ref: (1, H+R-1, W+S-1, C) — full padded image, VMEM-pinned.
    w_ref: (R, S, C, TK) — one output-channel slab.
    o_ref: (1, H, W, TK).
    """
    C = x_ref.shape[-1]
    TK = w_ref.shape[-1]
    acc = jnp.zeros((H * W, TK), jnp.float32)
    for r in range(R):          # static taps — fully unrolled, MXU-pipelined
        for s in range(S):
            xs = x_ref[0, r:r + H, s:s + W, :].reshape(H * W, C)
            acc += jnp.dot(xs, w_ref[r, s],
                           preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(H, W, TK).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def ilpm_conv(x_padded, w, *, block_k: int = 128, interpret: bool = False):
    """x_padded: (B, H+R-1, W+S-1, C) pre-padded; w: (R,S,C,K) -> (B,H,W,K)."""
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H, W = Hp - R + 1, Wp - S + 1
    tk = min(block_k, K)
    grid = (B, pl.cdiv(K, tk))
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S),
        grid=grid,
        in_specs=[
            # index map ignores k -> image stays resident across the K row
            pl.BlockSpec((1, Hp, Wp, C), lambda b, k: (b, 0, 0, 0)),
            pl.BlockSpec((R, S, C, tk), lambda b, k: (0, 0, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, H, W, tk), lambda b, k: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x_padded.dtype),
        interpret=interpret,
    )(x_padded, w)
