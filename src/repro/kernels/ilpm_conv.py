"""ILP-M convolution — the paper's contribution, as a Pallas TPU kernel.

TPU adaptation of the algorithm (DESIGN.md §2):
  * output channels K on the LANE dimension (the paper maps threads -> K);
  * the (padded) input image tile is **VMEM-resident across the whole grid
    row** — its BlockSpec index map ignores the K grid axis, so Pallas keeps
    it on-chip and never refetches it (the paper's shared-memory image tile,
    minus the barrier);
  * filters in HWIO ([R][S][C][K], K minor) — the paper's [C][R][S][K]
    coalesced layout, lane-aligned on TPU;
  * static tap loop: each (r, s) step is one `(H·W, C) @ (C, K_blk)` MXU
    contraction — one weight slab amortized over every pixel of the tile,
    the `workgroup_size : 1` arithmetic:load ratio of the paper;
  * stride ∈ {1, 2}: the tap windows are strided slices of the resident
    image, so strided layers (the ResNet stem's 7×7/2, stage-entry 3×3/2)
    keep the same image-residency structure instead of escaping to XLA;
  * optional fused epilogue: folded-BN `y*scale + bias` and ReLU/ReLU6
    applied to the accumulator before the single output write — the
    conv+BN+act triple costs one HBM pass instead of three.

Single-image (B small) is the design premise, exactly as in the paper: the
pixel axis, not the batch axis, feeds the sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fusion import epilogue_operands
from repro.kernels.ref import apply_act


def _kernel(x_ref, w_ref, *refs, H, W, R, S, stride, act, fused):
    """x_ref: (1, Hp, Wp, C) — full padded image, VMEM-pinned.
    w_ref: (R, S, C, TK) — one output-channel slab.
    refs: optional (scale, bias) (1, TK) slabs, then o_ref (1, H, W, TK).
    """
    o_ref = refs[-1]
    C = x_ref.shape[-1]
    TK = w_ref.shape[-1]
    acc = jnp.zeros((H * W, TK), jnp.float32)
    for r in range(R):          # static taps — fully unrolled, MXU-pipelined
        for s in range(S):
            xs = x_ref[0, r:r + (H - 1) * stride + 1:stride,
                       s:s + (W - 1) * stride + 1:stride, :].reshape(H * W, C)
            acc += jnp.dot(xs, w_ref[r, s],
                           preferred_element_type=jnp.float32)
    if fused:
        acc = acc * refs[0][0] + refs[1][0]
    acc = apply_act(acc, act)
    o_ref[0] = acc.reshape(H, W, TK).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "block_k", "act", "interpret"))
def ilpm_conv(x_padded, w, *, stride: int = 1, block_k: int = 128,
              scale=None, bias=None, act=None, interpret: bool = False):
    """x_padded: (B, (H-1)*stride+R, (W-1)*stride+S, C) pre-padded;
    w: (R,S,C,K) -> (B,H,W,K).

    ``scale``/``bias`` are optional (K,) folded-BN vectors and ``act`` an
    optional activation name ('relu' | 'relu6'), all applied inside the
    kernel's output write.
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    tk = min(block_k, K)
    grid = (B, pl.cdiv(K, tk))
    operands = [x_padded, w]
    in_specs = [
        # index map ignores k -> image stays resident across the K row
        pl.BlockSpec((1, Hp, Wp, C), lambda b, k: (b, 0, 0, 0)),
        pl.BlockSpec((R, S, C, tk), lambda b, k: (0, 0, 0, k)),
    ]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, tk, lambda b, k: (0, k))
    operands += extra
    in_specs += extra_specs
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S, stride=stride,
                          act=act, fused=fused),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, W, tk), lambda b, k: (b, 0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x_padded.dtype),
        interpret=interpret,
    )(*operands)
