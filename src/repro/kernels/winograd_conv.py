"""Winograd F(2x2, 3x3) convolution — baseline (§3.2, Lavin & Gray).

Three phases mirroring the paper's profile rows: input transform kernel
(winograd_trans_from_image), 16 batched GEMMs (winograd_gemm x16), output
inverse transform (winograd_trans_to_output). The filter transform is
constant at inference and precomputed offline (paper §5.2). The transforms'
extra HBM traffic — V is 4x the input for stride-2 4x4 tiles — is the cost
the paper charges against Winograd on bandwidth-starved devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels.fusion import epilogue_operands
from repro.kernels.gemm import gemm

winograd_filter_transform = _ref.winograd_filter_transform


def _bt_combine(rows):
    """B^T combination along one axis: rows = [d0,d1,d2,d3] -> 4 outputs.

    Winograd input transform is pure add/sub — no multiplies (the whole
    point of the algorithm): [d0-d2, d1+d2, d2-d1, d1-d3].
    """
    d0, d1, d2, d3 = rows
    return [d0 - d2, d1 + d2, d2 - d1, d1 - d3]


def _trans_in_kernel(x_ref, o_ref, *, TH, TW):
    """x_ref: (1, 2*TH+2, 2*TW+2, C) image; o_ref: (1, 4, 4, TH*TW, C).

    B^T d B applied to 4x4 windows at stride 2, entirely in VMEM,
    hand-coded as adds/subs (Winograd transforms have no multiplies).
    """
    C = x_ref.shape[-1]
    # gather stride-2 4x4 windows: (TH, TW, 4, 4, C)
    rows = [x_ref[0, 2 * i:2 * i + 4] for i in range(TH)]
    d = jnp.stack(rows, axis=0)                         # (TH, 4, Wp, C)
    cols = [d[:, :, 2 * j:2 * j + 4, :] for j in range(TW)]
    d = jnp.stack(cols, axis=1)                          # (TH, TW, 4, 4, C)
    r = _bt_combine([d[:, :, i] for i in range(4)])      # over the r axis
    v = [_bt_combine([ra[:, :, j] for j in range(4)]) for ra in r]
    v = jnp.stack([jnp.stack(vr, axis=2) for vr in v], axis=2)  # (TH,TW,4,4,C)
    v = v.transpose(2, 3, 0, 1, 4)
    o_ref[0] = v.reshape(4, 4, TH * TW, C).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def winograd_input_transform(x_padded, *, interpret=False):
    """(B, H+2, W+2, C) -> V (B, 4, 4, (H/2)*(W/2), C)."""
    B, Hp, Wp, C = x_padded.shape
    H, W = Hp - 2, Wp - 2
    th, tw = H // 2, W // 2
    return pl.pallas_call(
        functools.partial(_trans_in_kernel, TH=th, TW=tw),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, Hp, Wp, C), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 4, 4, th * tw, C), lambda b: (b, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 4, 4, th * tw, C), x_padded.dtype),
        interpret=interpret,
    )(x_padded)


def _at_combine(rows):
    """A^T combination: [m0+m1+m2, m1-m2-m3] — again add/sub only."""
    m0, m1, m2, m3 = rows
    return [m0 + m1 + m2, m1 - m2 - m3]


def _trans_out_kernel(m_ref, *refs, TH, TW, act, fused):
    """m_ref: (1, 4, 4, TH*TW, K); refs: optional (scale, bias) (1, K),
    then o_ref (1, 2*TH, 2*TW, K)."""
    o_ref = refs[-1]
    K = m_ref.shape[-1]
    m = m_ref[0].astype(jnp.float32)                     # (4,4,nt,K)
    t = _at_combine([m[i] for i in range(4)])            # 2 x (4,nt,K)
    y = [[None, None], [None, None]]
    for a in range(2):
        ya = _at_combine([t[a][j] for j in range(4)])    # 2 x (nt,K)
        y[a][0], y[a][1] = ya
    y = jnp.stack([jnp.stack(row, axis=0) for row in y], axis=0)  # (2,2,nt,K)
    y = y.transpose(2, 0, 1, 3).reshape(TH, TW, 2, 2, K).transpose(0, 2, 1, 3, 4)
    y = y.reshape(2 * TH, 2 * TW, K)
    if fused:
        y = y * refs[0][0] + refs[1][0]
    y = _ref.apply_act(y, act)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("H", "W", "act", "interpret"))
def winograd_output_transform(m, *, H, W, scale=None, bias=None, act=None,
                              interpret=False):
    B = m.shape[0]
    K = m.shape[-1]
    th, tw = H // 2, W // 2
    operands = [m]
    in_specs = [pl.BlockSpec((1, 4, 4, th * tw, K),
                             lambda b: (b, 0, 0, 0, 0))]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, K, lambda b: (0, 0))  # single-block grid: full K
    operands += extra
    in_specs += extra_specs
    return pl.pallas_call(
        functools.partial(_trans_out_kernel, TH=th, TW=tw, act=act,
                          fused=fused),
        grid=(B,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, W, K), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), m.dtype),
        interpret=interpret,
    )(*operands)


def winograd_conv(x_padded, w, *, u=None, scale=None, bias=None, act=None,
                  interpret=False):
    """Full pipeline. `u` (precomputed filter transform) optional — at
    inference weights are frozen, so the engine computes `U = G g Gᵀ` once
    per plan build and passes it here; the (scale, bias, act) epilogue is
    folded into the output-transform kernel's write."""
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    assert (R, S) == (3, 3)
    H, W = Hp - 2, Wp - 2
    assert H % 2 == 0 and W % 2 == 0, "winograd F(2,3): even output dims"
    if u is None:
        u = winograd_filter_transform(w)                # (4,4,C,K) offline
    v = winograd_input_transform(x_padded, interpret=interpret)
    # 16 batched GEMMs: (nt, C) @ (C, K) per (xi, nu)
    vf = v.reshape(B, 16, -1, C)
    uf = u.reshape(16, C, K)
    m = jax.vmap(lambda vb: jax.vmap(
        lambda vt, ut: gemm(vt, ut, interpret=interpret))(vb, uf))(vf)
    m = m.reshape(B, 4, 4, -1, K)
    return winograd_output_transform(m, H=H, W=W, scale=scale, bias=bias,
                                     act=act, interpret=interpret)
