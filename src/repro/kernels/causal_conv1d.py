"""Depthwise causal conv1d — the paper's technique applied to Mamba stems.

ILP-M structure in 1D: channels on the LANE dimension (the paper's
thread->output-channel mapping), the sequence tile VMEM-resident, the k taps
statically unrolled with one broadcast weight row per tap (one register per
weight — the paper's register-minimization). The causal halo (k-1 leading
elements) comes from a second BlockSpec view of the *previous* tile, so
blocks never overlap and the pipeline stays a pure sliding window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xprev_ref, x_ref, w_ref, b_ref, o_ref, *, K, TL, first_tile_zero):
    """xprev_ref/x_ref: (1, TL, C); w_ref: (K, C); o_ref: (1, TL, C)."""
    C = x_ref.shape[-1]
    i = pl.program_id(1)
    halo = xprev_ref[0, TL - (K - 1):, :]               # (K-1, C)
    halo = jnp.where(i == 0, jnp.zeros_like(halo), halo)  # causal left edge
    xt = jnp.concatenate([halo, x_ref[0]], axis=0)       # (TL+K-1, C)
    acc = jnp.zeros((TL, C), jnp.float32)
    for j in range(K):  # static taps: one broadcast weight row per step
        acc += xt[j:j + TL, :].astype(jnp.float32) * w_ref[j, :].astype(jnp.float32)
    acc += b_ref[:].astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def causal_conv1d(x, w, b=None, *, block_l: int = 512, interpret: bool = False):
    """x: (B, L, C); w: (K, C); b: (C,) -> (B, L, C), causal (left-padded)."""
    B, L, C = x.shape
    K = w.shape[0]
    if b is None:
        b = jnp.zeros((C,), x.dtype)
    tl = min(block_l, L)
    pad = (-L) % tl
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    n = (L + pad) // tl
    out = pl.pallas_call(
        functools.partial(_kernel, K=K, TL=tl, first_tile_zero=True),
        grid=(B, n),
        in_specs=[
            # previous tile (for the causal halo); clamped at i == 0
            pl.BlockSpec((1, tl, C),
                         lambda bidx, i: (bidx, jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((1, tl, C), lambda bidx, i: (bidx, i, 0)),
            pl.BlockSpec((K, C), lambda bidx, i: (0, 0)),
            pl.BlockSpec((C,), lambda bidx, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tl, C), lambda bidx, i: (bidx, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L + pad, C), x.dtype),
        interpret=interpret,
    )(x, x, w, b)
    return out[:, :L]
