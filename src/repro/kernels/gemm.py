"""Tiled GEMM Pallas kernel (clBLAS stand-in for im2col/winograd phases).

Classic MXU tiling: grid (M/TM, N/TN, K/TK) with the K axis innermost; the
output block's index map ignores k, so the fp32 accumulator tile stays in
VMEM across the contraction (revisiting), zero-initialized at k == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def gemm(a, b, *, block_m=256, block_n=128, block_k=128, interpret=False):
    """a: (M, Kc), b: (Kc, N) -> (M, N)."""
    M, Kc = a.shape
    _, N = b.shape
    tm, tn, tk = min(block_m, M), min(block_n, N), min(block_k, Kc)
    # zero-pad the contraction dim so partial K blocks never read garbage
    if Kc % tk:
        pad = tk - Kc % tk
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
        Kc += pad
    grid = (pl.cdiv(M, tm), pl.cdiv(N, tn), pl.cdiv(Kc, tk))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tk, tn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[_acc_scratch(tm, tn)],
        interpret=interpret,
    )(a, b)


def _acc_scratch(tm, tn):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((tm, tn), jnp.float32)
