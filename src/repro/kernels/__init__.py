"""Pallas TPU kernels for the paper's compute hot-spots.

One module per algorithm (pl.pallas_call + explicit BlockSpec VMEM tiling),
`ops.py` as the jit'd dispatch wrappers, `ref.py` as the pure-jnp oracles:

    ilpm_conv      — the paper's contribution (K on lanes, taps unrolled,
                     image VMEM-resident)
    direct_conv    — pixel-major baseline (filter bank resident)
    im2col_conv    — two-kernel unroll + GEMM (the HBM round-trip)
    libdnn_conv    — fused on-the-fly unroll
    winograd_conv  — F(2x2,3x3): transforms + 16 batched GEMMs
    causal_conv1d  — the technique in 1D (Mamba/Jamba conv stems)
    gemm           — tiled MXU matmul used by im2col/winograd phases
    fused_block    — per-BLOCK megakernels (inverted residual with the
                     expanded tensor VMEM-only; residual-add-fused conv);
                     dispatched via ops.dispatch_block
"""
from repro.kernels import ops, ref  # noqa: F401
