"""libdnn-style fused convolution — baseline (§3.1, Tschopp's OpenCL Caffe).

im2col and GEMM fused in ONE kernel: each grid step builds the patch tile
for its (pixel, K) GEMM tile **on the fly in VMEM** and immediately
contracts it — the unrolled matrix never exists in HBM. The paper's
critique survives the TPU port: every K-tile revisits the same pixels, so
the unroll work (gathers + index math) is redone K/TK times — visible here
as the re-sliced reshape per grid step versus ILP-M's single resident image.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, H, W, R, S):
    """x_ref: (1, Hp, Wp, C); w_ref: (R*S*C, TK); o_ref: (1, H*W, TK)."""
    C = x_ref.shape[-1]
    # fused unroll: build the patch tile in VMEM registers...
    cols = []
    for r in range(R):
        for s in range(S):
            cols.append(x_ref[0, r:r + H, s:s + W, :].reshape(H * W, C))
    patch = jnp.concatenate(cols, axis=-1)          # (H*W, R*S*C)
    # ...then contract immediately (never leaves the chip)
    o_ref[0] = jnp.dot(patch, w_ref[...],
                       preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def libdnn_conv(x_padded, w, *, block_k: int = 128, interpret: bool = False):
    """x_padded: (B,Hp,Wp,C); w: (R,S,C,K) -> (B,H,W,K)."""
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H, W = Hp - R + 1, Wp - S + 1
    tk = min(block_k, K)
    wf = w.reshape(R * S * C, K)
    out = pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S),
        grid=(B, pl.cdiv(K, tk)),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, k: (b, 0, 0, 0)),
            pl.BlockSpec((R * S * C, tk), lambda b, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, H * W, tk), lambda b, k: (b, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H * W, K), x_padded.dtype),
        interpret=interpret,
    )(x_padded, wf)
    return out.reshape(B, H, W, K)
