"""libdnn-style fused convolution — baseline (§3.1, Tschopp's OpenCL Caffe).

im2col and GEMM fused in ONE kernel: each grid step builds the patch tile
for its (pixel, K) GEMM tile **on the fly in VMEM** and immediately
contracts it — the unrolled matrix never exists in HBM. The paper's
critique survives the TPU port: every K-tile revisits the same pixels, so
the unroll work (gathers + index math) is redone K/TK times — visible here
as the re-sliced reshape per grid step versus ILP-M's single resident image.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


from repro.kernels.fusion import epilogue_operands
from repro.kernels.ref import apply_act


def _kernel(x_ref, w_ref, *refs, H, W, R, S, act, fused):
    """x_ref: (1, Hp, Wp, C); w_ref: (R*S*C, TK); refs: optional
    (scale, bias) (1, TK) slabs, then o_ref (1, H*W, TK)."""
    o_ref = refs[-1]
    C = x_ref.shape[-1]
    # fused unroll: build the patch tile in VMEM registers...
    cols = []
    for r in range(R):
        for s in range(S):
            cols.append(x_ref[0, r:r + H, s:s + W, :].reshape(H * W, C))
    patch = jnp.concatenate(cols, axis=-1)          # (H*W, R*S*C)
    # ...then contract immediately (never leaves the chip)
    acc = jnp.dot(patch, w_ref[...], preferred_element_type=jnp.float32)
    if fused:
        acc = acc * refs[0][0] + refs[1][0]
    acc = apply_act(acc, act)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "act", "interpret"))
def libdnn_conv(x_padded, w, *, block_k: int = 128, scale=None, bias=None,
                act=None, interpret: bool = False):
    """x_padded: (B,Hp,Wp,C); w: (R,S,C,K) -> (B,H,W,K)."""
    B, Hp, Wp, C = x_padded.shape
    R, S, _, K = w.shape
    H, W = Hp - R + 1, Wp - S + 1
    tk = min(block_k, K)
    wf = w.reshape(R * S * C, K)
    operands = [x_padded, wf]
    in_specs = [
        pl.BlockSpec((1, Hp, Wp, C), lambda b, k: (b, 0, 0, 0)),
        pl.BlockSpec((R * S * C, tk), lambda b, k: (0, k)),
    ]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, tk, lambda b, k: (0, k))
    operands += extra
    in_specs += extra_specs
    out = pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S, act=act, fused=fused),
        grid=(B, pl.cdiv(K, tk)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H * W, tk), lambda b, k: (b, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, H * W, K), x_padded.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, H, W, K)
