"""Depthwise convolution — the MobileNet-family workhorse, ILP-M style.

Depthwise layers dominate mobile inference time (Zhang et al. 2020) and are
pure VPU work on TPU: each channel convolves only itself, so there is no
C-contraction to feed the MXU. The ILP-M blocking transfers directly:

  * channels C on the LANE dimension (the paper maps threads -> output
    channels; depthwise output channels == input channels);
  * the (padded) image tile is **VMEM-resident across the whole grid row**
    — its channel slab's index map ignores nothing it doesn't have to, and
    each grid step owns a `block_c` channel slab end-to-end (image slab,
    filter slab, output slab all cut on the same axis), so nothing is
    refetched;
  * static tap loop: each (r, s) step is one strided window load times one
    per-channel filter row, `H·W : 1` arithmetic:load on the filter operand
    — the paper's `workgroup_size : 1` ratio, elementwise instead of MXU.

Stride 1 and 2 both run in-kernel (MobileNet downsamples inside its
depthwise layers), unlike the dense kernels where stride-2 falls to XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, H, W, R, S, stride):
    """x_ref: (1, Hp, Wp, TC) padded image channel slab, VMEM-pinned.
    w_ref: (R, S, 1, TC) — the slab's per-channel filter taps.
    o_ref: (1, H, W, TC).
    """
    x = x_ref[0]
    TC = x.shape[-1]
    acc = jnp.zeros((H, W, TC), jnp.float32)
    for r in range(R):          # static taps — fully unrolled, VPU-pipelined
        for s in range(S):
            xs = x[r:r + (H - 1) * stride + 1:stride,
                   s:s + (W - 1) * stride + 1:stride, :]
            acc += xs.astype(jnp.float32) * w_ref[r, s, 0].astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "block_c", "interpret"))
def depthwise_conv(x_padded, w, *, stride: int = 1, block_c: int = 128,
                   interpret: bool = False):
    """x_padded: (B, Hp, Wp, C) pre-padded; w: (R, S, 1, C) -> (B, H, W, C).

    ``block_c`` tiles the channel axis (the tuned kernel parameter); the
    grid is (batch, channel blocks) and every operand of one grid step is
    the same channel slab, so VMEM holds image + filters + output for
    `block_c` lanes at once.
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, cg, K = w.shape
    assert cg == 1 and K == C, (
        f"depthwise kernel wants (R,S,1,C) filters for C={C}, got {w.shape}")
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    tc = min(block_c, C)
    grid = (B, pl.cdiv(C, tc))
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S, stride=stride),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, tc), lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((R, S, 1, tc), lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, tc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, C), x_padded.dtype),
        interpret=interpret,
    )(x_padded, w)
