"""Depthwise convolution — the MobileNet-family workhorse, ILP-M style.

Depthwise layers dominate mobile inference time (Zhang et al. 2020) and are
pure VPU work on TPU: each channel convolves only itself, so there is no
C-contraction to feed the MXU. The ILP-M blocking transfers directly:

  * channels C on the LANE dimension (the paper maps threads -> output
    channels; depthwise output channels == input channels);
  * the (padded) image tile is **VMEM-resident across the whole grid row**
    — its channel slab's index map ignores nothing it doesn't have to, and
    each grid step owns a `block_c` channel slab end-to-end (image slab,
    filter slab, output slab all cut on the same axis), so nothing is
    refetched;
  * static tap loop: each (r, s) step is one strided window load times one
    per-channel filter row, `H·W : 1` arithmetic:load on the filter operand
    — the paper's `workgroup_size : 1` ratio, elementwise instead of MXU.

Stride 1 and 2 both run in-kernel (MobileNet downsamples inside its
depthwise layers). Channel multipliers > 1 are supported with lax's HWIO
convention — filters (R, S, 1, M·C), output channel k reading input channel
k // M — by repeating the input slab M× on lanes inside the kernel. An
optional (scale, bias, act) epilogue folds BN + ReLU6 into the output
write, same contract as the dense kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fusion import epilogue_operands
from repro.kernels.ref import apply_act


def _kernel(x_ref, w_ref, *refs, H, W, R, S, stride, mult, act, fused):
    """x_ref: (1, Hp, Wp, TC) padded image channel slab, VMEM-pinned.
    w_ref: (R, S, 1, TK) — the slab's per-channel filter taps (TK = M·TC).
    refs: optional (scale, bias) (1, TK) slabs, then o_ref (1, H, W, TK).
    """
    o_ref = refs[-1]
    x = x_ref[0]
    TK = w_ref.shape[-1]
    acc = jnp.zeros((H, W, TK), jnp.float32)
    for r in range(R):          # static taps — fully unrolled, VPU-pipelined
        for s in range(S):
            xs = x[r:r + (H - 1) * stride + 1:stride,
                   s:s + (W - 1) * stride + 1:stride, :]
            if mult > 1:        # channel k convolves input channel k // M
                xs = jnp.repeat(xs, mult, axis=-1)
            acc += xs.astype(jnp.float32) * w_ref[r, s, 0].astype(jnp.float32)
    if fused:
        acc = acc * refs[0][0] + refs[1][0]
    acc = apply_act(acc, act)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "block_c", "act", "interpret"))
def depthwise_conv(x_padded, w, *, stride: int = 1, block_c: int = 128,
                   scale=None, bias=None, act=None, interpret: bool = False):
    """x_padded: (B, Hp, Wp, C) pre-padded; w: (R, S, 1, M·C)
    -> (B, H, W, M·C).

    ``block_c`` tiles the *output*-channel axis (the tuned kernel
    parameter); the grid is (batch, channel blocks) and every operand of
    one grid step is the same channel slab — for multiplier M the image
    slab carries ``block_c // M`` input channels feeding ``block_c``
    output lanes.
    """
    B, Hp, Wp, C = x_padded.shape
    R, S, cg, K = w.shape
    assert cg == 1 and K % C == 0, (
        f"depthwise kernel wants (R,S,1,M*C) filters for C={C}, got {w.shape}")
    mult = K // C
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    tk = min(block_c, K)
    tk = max(mult, tk - tk % mult)  # output slab must hold whole input lanes
    tc = tk // mult
    grid = (B, pl.cdiv(K, tk))
    operands = [x_padded, w]
    in_specs = [
        pl.BlockSpec((1, Hp, Wp, tc), lambda b, c: (b, 0, 0, c)),
        pl.BlockSpec((R, S, 1, tk), lambda b, c: (0, 0, 0, c)),
    ]
    fused, extra, extra_specs = epilogue_operands(
        scale, bias, K, tk, lambda b, c: (0, c))
    operands += extra
    in_specs += extra_specs
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W, R=R, S=S, stride=stride,
                          mult=mult, act=act, fused=fused),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, W, tk), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x_padded.dtype),
        interpret=interpret,
    )(*operands)
