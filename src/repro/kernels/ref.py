"""Pure-jnp oracles for every kernel.

``conv2d_reference`` (lax.conv_general_dilated) is the ground truth; each
algorithm also has a structural reference that mirrors its data movement in
plain jnp (patches for im2col, tap loop for ilpm, Winograd transforms) so the
Pallas kernels can be checked against the *algorithm*, and every algorithm
against the ground truth.

Layouts: activations NHWC, filters HWIO (R, S, C, K) — the TPU adaptation of
the paper's [C][R][S][K] coalesced layout (K minor => lane-aligned).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def conv2d_reference(x, w, *, stride=1, padding="SAME", groups=1):
    """Ground truth. x: (B,H,W,C), w: (R,S,C/groups,K).

    ``groups`` is lax's ``feature_group_count``; ``groups == C == K`` is a
    depthwise conv with weights (R, S, 1, C).
    """
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def apply_act(y, act):
    """Apply a named activation ('relu' | 'relu6' | None).

    Shared by the Pallas kernels' fused epilogues (it is plain jnp, so it
    traces inside a kernel body) and the jnp reference paths.
    """
    if act is None:
        return y
    if act == "relu":
        return jnp.maximum(y, 0)
    if act == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    raise ValueError(f"unknown activation {act!r}")


def apply_epilogue(y, scale=None, bias=None, act=None):
    """Unfused conv epilogue: y*scale + bias then activation, in fp32.

    The jnp/XLA counterpart of the kernels' in-kernel epilogue — used by
    the `impl='jnp'` wrappers and the XLA escape hatch so fused and
    unfused paths compute the same function.
    """
    if scale is None and bias is None and act is None:
        return y
    z = y.astype(jnp.float32)
    if scale is not None:
        z = z * scale.astype(jnp.float32)
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    return apply_act(z, act).astype(y.dtype)


def pad_same(x, r, s, stride=1):
    """Explicit SAME padding so kernels see pre-padded inputs.

    Matches XLA's SAME convention: total pad (out-1)*stride + r - h split
    low-first; stride-1 reduces to the familiar symmetric (r-1)//2 halo.
    """
    h, w = x.shape[1], x.shape[2]
    ph = max((-(-h // stride) - 1) * stride + r - h, 0)
    pw = max((-(-w // stride) - 1) * stride + s - w, 0)
    return jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                       (pw // 2, pw - pw // 2), (0, 0)))


# ----------------------------------------------------------------------
# ILP-M: tap-major accumulation, image resident, K vectorized


def ilpm_conv(x_padded, w, *, stride=1):
    """x_padded: (B, (H-1)*stride+R, (W-1)*stride+S, C); w: (R,S,C,K)
    -> (B,H,W,K).

    The algorithm's structure in jnp: static loop over taps, each tap a
    (pixels, C) @ (C, K) contraction — one weight slab per step amortized
    over the whole image tile (the paper's workgroup_size:1 ratio). Strided
    taps are strided windows of the same resident image.
    """
    R, S, C, K = w.shape
    B, Hp, Wp, _ = x_padded.shape
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    acc = jnp.zeros((B, H * W, K), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = x_padded[:, r:r + (H - 1) * stride + 1:stride,
                          s:s + (W - 1) * stride + 1:stride, :].reshape(
                              B, H * W, C)
            acc = acc + jnp.einsum("bpc,ck->bpk", xs, w[r, s],
                                   preferred_element_type=jnp.float32)
    return acc.reshape(B, H, W, K).astype(x_padded.dtype)


# ----------------------------------------------------------------------
# direct: pixel-major, full filter set resident


def direct_conv(x_padded, w, *, stride=1):
    """Same math, pixel-tile grid ordering; kept numerically identical —
    the structural difference (filter-set residency) is a kernel concern."""
    R, S, C, K = w.shape
    B, Hp, Wp, _ = x_padded.shape
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    # gather taps then one big contraction per pixel tile (filters stationary)
    taps = jnp.stack([x_padded[:, r:r + (H - 1) * stride + 1:stride,
                               s:s + (W - 1) * stride + 1:stride, :]
                      for r in range(R) for s in range(S)], axis=-2)
    return jnp.einsum("bhwtc,tck->bhwk", taps, w.reshape(R * S, C, K),
                      preferred_element_type=jnp.float32).astype(x_padded.dtype)


# ----------------------------------------------------------------------
# im2col: materialized patch matrix + GEMM (two phases)


def im2col_unroll(x_padded, r, s):
    """-> (B, H*W, R*S*C): the unrolled input matrix (HBM round-trip)."""
    R, S = r, s
    B, Hp, Wp, C = x_padded.shape
    H, W = Hp - R + 1, Wp - S + 1
    cols = [x_padded[:, i:i + H, j:j + W, :]
            for i in range(R) for j in range(S)]
    return jnp.concatenate(cols, axis=-1).reshape(B, H * W, R * S * C)


def im2col_conv(x_padded, w):
    R, S, C, K = w.shape
    B, Hp, Wp, _ = x_padded.shape
    H, W = Hp - R + 1, Wp - S + 1
    patches = im2col_unroll(x_padded, R, S)
    out = jnp.einsum("bpc,ck->bpk", patches, w.reshape(R * S * C, K),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, W, K).astype(x_padded.dtype)


# libdnn = fused im2col (identical math; fusion is a kernel concern)
libdnn_conv = im2col_conv


# ----------------------------------------------------------------------
# Winograd F(2x2, 3x3)

_BT = np.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], np.float32)
_G = np.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], np.float32)
_AT = np.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], np.float32)


def winograd_filter_transform(w):
    """(3,3,C,K) -> U (4,4,C,K). Constant at inference (paper §5.2)."""
    return jnp.einsum("ar,rsck,bs->abck", _G, w, _G)


def winograd_input_transform(x_padded, H, W):
    """Tile into 4x4 patches (stride 2) and apply B^T d B.

    -> V: (B, 4, 4, nt, C) with nt = (H/2)*(W/2) output tiles.
    """
    Bsz, Hp, Wp, C = x_padded.shape
    th, tw = H // 2, W // 2
    # gather 4x4 windows at stride 2
    d = jnp.stack([x_padded[:, 2 * i:2 * i + 4] for i in range(th)], axis=1)
    d = jnp.stack([d[:, :, :, 2 * j:2 * j + 4] for j in range(tw)], axis=2)
    # d: (B, th, tw, 4, 4, C)
    v = jnp.einsum("ar,bijrsc,ds->bijadc", _BT, d, _BT)
    return v.transpose(0, 3, 4, 1, 2, 5).reshape(Bsz, 4, 4, th * tw, C)


def winograd_output_transform(m, H, W):
    """m: (B,4,4,nt,K) -> (B,H,W,K) via A^T m A + tile scatter."""
    Bsz = m.shape[0]
    K = m.shape[-1]
    th, tw = H // 2, W // 2
    y = jnp.einsum("ar,brstk,ds->btadk", _AT, m, _AT)  # (B, nt, 2, 2, K)
    y = y.reshape(Bsz, th, tw, 2, 2, K).transpose(0, 1, 3, 2, 4, 5)
    return y.reshape(Bsz, H, W, K)


def winograd_conv(x_padded, w, *, u=None):
    """Full F(2x2,3x3) pipeline; requires even H, W. ``u`` optionally
    carries the precomputed filter transform (frozen at inference)."""
    R, S, C, K = w.shape
    assert (R, S) == (3, 3), "winograd F(2,3) is 3x3-only"
    B, Hp, Wp, _ = x_padded.shape
    H, W = Hp - 2, Wp - 2
    assert H % 2 == 0 and W % 2 == 0, "even output dims required"
    if u is None:
        u = winograd_filter_transform(w)                  # (4,4,C,K)
    v = winograd_input_transform(x_padded, H, W)          # (B,4,4,nt,C)
    m = jnp.einsum("bxytc,xyck->bxytk", v, u,
                   preferred_element_type=jnp.float32)    # 16 batched GEMMs
    return winograd_output_transform(m.astype(x_padded.dtype), H, W)


# ----------------------------------------------------------------------
# depthwise / pointwise (the MobileNet family factorization)


def depthwise_conv(x_padded, w, *, stride=1):
    """x_padded: (B, Hp, Wp, C) pre-padded; w: (R, S, 1, M*C)
    -> (B, H, W, M*C).

    The algorithm's structure in jnp: static tap loop, each tap a strided
    window of the resident image scaled by one per-channel filter row — all
    VPU work, no contraction (each channel convolves only itself). Channel
    multipliers M > 1 follow lax's HWIO convention: output channel k reads
    input channel k // M.
    """
    R, S, _, K = w.shape
    B, Hp, Wp, C = x_padded.shape
    assert K % C == 0, (w.shape, x_padded.shape)
    mult = K // C
    H = (Hp - R) // stride + 1
    W = (Wp - S) // stride + 1
    acc = jnp.zeros((B, H, W, K), jnp.float32)
    for r in range(R):
        for s in range(S):
            xs = x_padded[:, r:r + (H - 1) * stride + 1:stride,
                          s:s + (W - 1) * stride + 1:stride, :]
            if mult > 1:
                xs = jnp.repeat(xs, mult, axis=-1)
            acc = acc + xs.astype(jnp.float32) * w[r, s, 0].astype(jnp.float32)
    return acc.astype(x_padded.dtype)


def pointwise_conv(x, w, *, stride=1):
    """x: (B, H, W, C); w: (1, 1, C, K) -> (B, ceil(H/s), ceil(W/s), K).

    A 1x1 conv is one (pixels, C) @ (C, K) GEMM — no padding, no taps; a
    strided 1x1 (ResNet projection shortcut) just subsamples first."""
    if stride != 1:
        x = x[:, ::stride, ::stride, :]
    return jnp.einsum("bhwc,ck->bhwk", x, w[0, 0],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ----------------------------------------------------------------------
# fused blocks: the per-layer chains the megakernels replace, composed
# stage for stage from the references above (same casts, same op order —
# these ARE the per-layer semantics, so fused-vs-composed parity checks
# the fusion and nothing else)


def fused_inverted_residual(x, weights, *, stride=1, residual=False,
                            act="relu6", out_act=None):
    """Composed per-layer reference of the inverted-residual megakernel:
    expand (1x1 + BN/act) -> SAME pad -> depthwise (+ BN/act) -> project
    (1x1 + BN, linear) -> optional identity add. ``weights`` as in
    ``fused_block.fused_inverted_residual``; each stage's epilogue runs
    in fp32 and casts back to the compute dtype, exactly like the
    per-layer kernels' output writes."""
    h = x
    if weights.get("w1") is not None:
        h = apply_epilogue(pointwise_conv(h, weights["w1"]),
                           weights.get("s1"), weights.get("b1"), act)
    wdw = weights["wdw"]
    h = pad_same(h, wdw.shape[0], wdw.shape[1], stride)
    h = apply_epilogue(depthwise_conv(h, wdw, stride=stride),
                       weights.get("sdw"), weights.get("bdw"), act)
    h = apply_epilogue(pointwise_conv(h, weights["w2"]),
                       weights.get("s2"), weights.get("b2"), out_act)
    if residual:
        h = h + x
    return h


def fused_residual_conv(x_padded, weights, *, res, act="relu"):
    """Composed per-layer reference of the residual-conv megakernel: the
    conv + folded BN writes at the compute dtype, then the shortcut add
    and outer activation run as a separate (per-layer: extra HBM pass)
    step in the compute dtype."""
    h = apply_epilogue(ilpm_conv(x_padded, weights["w"]),
                       weights.get("scale"), weights.get("bias"), None)
    return apply_act(h + res, act)


# ----------------------------------------------------------------------
# depthwise causal conv1d (Mamba stem) — the paper's technique in 1D


def causal_conv1d(x, w, b=None):
    """x: (B, L, C); w: (k, C) depthwise; left-padded (causal)."""
    k = w.shape[0]
    acc = jnp.zeros(x.shape, jnp.float32)
    for j in range(k):
        shift = k - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        acc = acc + xs.astype(jnp.float32) * w[j].astype(jnp.float32)
    if b is not None:
        acc = acc + b.astype(jnp.float32)
    return acc.astype(x.dtype)


def conv1d_dense(x, w, b=None, *, stride=1):
    """x: (B,L,Cin); w: (k,Cin,Cout) dense 1D conv, SAME padding."""
    k = w.shape[0]
    y = jax.lax.conv_general_dilated(
        x[:, :, None, :], w[:, None], window_strides=(stride, 1),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))[:, :, 0]
    if b is not None:
        y = y + b
    return y


def gemm(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
