"""Fault tolerance: checkpoint/restart loop, straggler watch, elasticity.

Designed for 1000+ node fleets where *something* is always failing:

  * ``resilient_train``: the train loop is a pure function of
    (state, step) -> state; any exception (device loss, preemption, numeric
    blowup configured as fatal) rolls back to the last committed checkpoint
    and replays — correct because the data pipeline is (seed, step)-pure.
  * ``StragglerWatch``: per-step deadline from a running p50; breaches are
    counted and surfaced so the scheduler can evict the slow host (on-fleet
    action; here it raises after `max_breaches` to trigger the restart path,
    which on a real cluster lands on a fresh machine set).
  * ``elastic_remesh``: rebuilds the mesh from surviving devices (largest
    (data, model) grid that still divides the model axes), re-shards the
    host-resident checkpoint onto it, and re-lowers the step — scale-down
    without losing the run.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field

import jax
import numpy as np

log = logging.getLogger("repro.runtime")


@dataclass
class StragglerWatch:
    factor: float = 3.0        # deadline = factor * running p50
    max_breaches: int = 5
    warmup: int = 3            # ignore compile steps
    times: list = field(default_factory=list)
    breaches: int = 0

    def observe(self, dt: float):
        self.times.append(dt)
        hist = self.times[self.warmup:]
        if len(hist) < 5:
            return
        p50 = float(np.median(hist))
        if dt > self.factor * p50:
            self.breaches += 1
            log.warning("straggler: step took %.3fs vs p50 %.3fs (%d/%d)",
                        dt, p50, self.breaches, self.max_breaches)
            if self.breaches >= self.max_breaches:
                raise RuntimeError(
                    "persistent straggler detected — requesting reschedule")


class TransientFailure(Exception):
    """The repo-wide transient-error type: raised by hardware/injection
    to exercise the restart path here, and re-exported by
    ``repro.serving.resilience`` as the retryable class for serving-side
    dispatch/build faults (anything else is treated as persistent)."""


def resilient_train(*, state, train_step, pipeline, ckpt, total_steps,
                    start_step=0, ckpt_every=50, max_failures=3,
                    straggler: StragglerWatch | None = None,
                    fail_injector=None, mesh=None, rules=None,
                    on_metrics=None):
    """Run to `total_steps` surviving up to `max_failures` restarts.

    Returns (state, step, n_restarts). `fail_injector(step)` may raise to
    simulate faults (used by the tests).
    """
    step = start_step
    failures = 0
    while step < total_steps:
        try:
            while step < total_steps:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.perf_counter()
                batch = pipeline.batch(step, mesh=mesh, rules=rules)
                state, metrics = train_step(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                if straggler is not None:
                    straggler.observe(dt)
                if on_metrics is not None:
                    on_metrics(step, metrics, dt)
                step += 1
                if step % ckpt_every == 0 or step == total_steps:
                    ckpt.save(step, state)
        except (TransientFailure, RuntimeError) as e:  # noqa: PERF203
            failures += 1
            log.warning("step %d failed (%s); restart %d/%d",
                        step, e, failures, max_failures)
            if failures > max_failures:
                raise
            ckpt.wait()
            restored_step, host_state = ckpt.restore()
            if host_state is None:
                step = start_step  # no checkpoint yet: replay from the top
                continue
            state = _device_put_like(host_state, state)
            step = restored_step
    ckpt.wait()
    return state, step, failures


def _device_put_like(host_tree, like_tree):
    """Restore host arrays onto the shardings of the live state."""
    return jax.tree.map(
        lambda h, l: jax.device_put(np.asarray(h).astype(l.dtype),
                                    l.sharding),
        host_tree, like_tree)


def elastic_remesh(n_devices: int, model_dims: list[int], *, devices=None):
    """Largest (data, model) mesh on `n_devices` whose model axis divides
    every dim in `model_dims` (vocab/heads/d_ff...). Scale-down re-mesh."""
    devices = devices if devices is not None else jax.devices()[:n_devices]
    n = len(devices)
    best = (n, 1)
    for model in range(min(n, 64), 0, -1):
        if n % model:
            continue
        if all(d % model == 0 for d in model_dims):
            best = (n // model, model)
            break
    mesh_devices = np.array(devices[: best[0] * best[1]]).reshape(best)
    from jax.sharding import Mesh

    return Mesh(mesh_devices, ("data", "model"))
