from repro.runtime.fault_tolerance import (  # noqa: F401
    StragglerWatch,
    TransientFailure,
    elastic_remesh,
    resilient_train,
)
