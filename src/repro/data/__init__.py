from repro.data.pipeline import TokenPipeline, prefetch  # noqa: F401
