"""Data pipeline: deterministic, sharded, restart-safe.

Fault-tolerance contract: batch(step) is a pure function of (seed, step),
so a restarted job resumes from checkpoint step N and regenerates exactly
the batches N, N+1, ... — no data-loader state to snapshot (skip-ahead
determinism). Host sharding: each process materializes only its addressable
shard of the global batch and assembles a global jax.Array.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import logical_sharding


@dataclasses.dataclass
class TokenPipeline:
    """Synthetic LM token stream (plus a file-backed mode for real corpora)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus: np.ndarray | None = None  # optional (N,) token memmap

    def _host_batch(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at `step` — pure in (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        if self.corpus is not None:
            starts = rng.integers(0, len(self.corpus) - self.seq_len - 1,
                                  size=self.global_batch)
            rows = np.stack([self.corpus[s:s + self.seq_len + 1]
                             for s in starts[lo:hi]])
        else:
            rows = rng.integers(0, self.vocab_size,
                                size=(self.global_batch, self.seq_len + 1),
                                dtype=np.int32)[lo:hi]
        return rows.astype(np.int32)

    def batch(self, step: int, mesh=None, rules=None) -> dict:
        """-> {'tokens': (B,S) int32, 'labels': (B,S) int32} global arrays."""
        rows = self._host_batch(step, 0, self.global_batch)
        tokens, labels = rows[:, :-1], rows[:, 1:]
        if mesh is None or mesh.empty:
            return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        sh = logical_sharding(("batch", "seq"), tokens.shape, rules, mesh)
        return {"tokens": jax.device_put(tokens, sh),
                "labels": jax.device_put(labels, sh)}


def prefetch(iterator, depth: int = 2):
    """Software pipelining: keep `depth` batches in flight ahead of compute."""
    import collections
    import threading
    import queue

    q: queue.Queue = queue.Queue(maxsize=depth)
    _DONE = object()

    def worker():
        try:
            for item in iterator:
                q.put(item)
        finally:
            q.put(_DONE)

    threading.Thread(target=worker, daemon=True).start()
    while True:
        item = q.get()
        if item is _DONE:
            return
        yield item
