"""Wire protocol for the serving front door — framing + a socket endpoint.

The deployment shape the paper implies (and CNNdroid makes explicit) is a
service fielding individual single-image requests from interactive apps.
This module puts a socket in front of ``Server``: a length-prefixed
binary framing that any client can speak, and ``ServerEndpoint``, the
threaded acceptor that decodes request frames into ``Server.submit``
calls and turns settled ``Ticket``s back into response frames.

Frame layout (network byte order, stdlib ``struct`` + JSON — no wire
dependency the container doesn't already have)::

    !I  body_length                  (bounded by MAX_FRAME_BYTES)
    body:
      !H  header_length
      header_length bytes of UTF-8 JSON   (the metadata header)
      remaining bytes: raw payload        (float32 image / logits data)

Request headers carry ``{v, type: "classify", id, network, shape,
image_dtype, dtype, deadline_ms, priority}``; response headers carry
``{v, type: "result", id, status, shape | message}``. Images and logits
travel as contiguous float32 — bf16/fp16 values widen to fp32 exactly,
so the wire never perturbs the bitwise-equal-to-``engine.run`` contract.

Typed rejections from the resilience layer cross the wire as **status
codes** (``overloaded`` / ``deadline_exceeded`` / ``circuit_open``), and
``serving/client.py`` re-raises them as the same exception types — a
remote caller sees exactly the errors an in-process one does. Malformed
frames are a ``bad_request`` response when the stream is still parseable
and a closed connection when it is not; either way the client never
hangs (``tests/test_protocol.py`` fuzzes this).
"""
from __future__ import annotations

import json
import socket
import struct
import threading

import numpy as np

from repro.serving.resilience import (
    CircuitOpen,
    DeadlineExceeded,
    Overloaded,
    Rejected,
)

PROTOCOL_VERSION = 1
# hard ceiling on one frame's body: a corrupt or hostile length prefix
# must never make a reader allocate gigabytes. 64 MiB >> any (H, W, C)
# float32 image this repo serves.
MAX_FRAME_BYTES = 64 << 20

_LEN = struct.Struct("!I")    # body length prefix
_HLEN = struct.Struct("!H")   # JSON header length inside the body

# status codes a response frame can carry, and the exception each one
# re-raises client-side. ``ok`` is the success status; ``bad_request``
# and ``internal_error`` map to wire-tier types below.
STATUS_OK = "ok"
STATUS_OVERLOADED = "overloaded"
STATUS_DEADLINE = "deadline_exceeded"
STATUS_CIRCUIT = "circuit_open"
STATUS_BAD_REQUEST = "bad_request"
STATUS_INTERNAL = "internal_error"


class ProtocolError(RuntimeError):
    """The byte stream violated the framing (truncated frame, oversized
    length prefix, malformed header). The connection is unrecoverable —
    readers close it rather than resynchronize."""


class BadRequest(ProtocolError):
    """A well-framed request the server cannot serve (unknown network,
    bad shape, wrong payload size). Travels as ``bad_request`` status —
    the connection itself stays usable."""


class RemoteError(RuntimeError):
    """The server failed internally on this request (``internal_error``
    status): the dispatch raised something that is not a typed
    rejection. The message carries the server-side exception text."""


def status_for(exc: BaseException) -> str:
    """Map a server-side exception to its wire status code."""
    if isinstance(exc, Overloaded):
        return STATUS_OVERLOADED
    if isinstance(exc, DeadlineExceeded):
        return STATUS_DEADLINE
    if isinstance(exc, CircuitOpen):
        return STATUS_CIRCUIT
    if isinstance(exc, (BadRequest, Rejected)):
        return STATUS_BAD_REQUEST
    return STATUS_INTERNAL


def error_for(status: str, message: str) -> BaseException:
    """Re-raise side: the client-side exception for a non-ok status."""
    if status == STATUS_OVERLOADED:
        return Overloaded(message)
    if status == STATUS_DEADLINE:
        return DeadlineExceeded(message)
    if status == STATUS_CIRCUIT:
        return CircuitOpen(message)
    if status == STATUS_BAD_REQUEST:
        return BadRequest(message)
    return RemoteError(message)


# ---------------------------------------------------------------------------
# framing


def pack_frame(header: dict, payload: bytes = b"") -> bytes:
    """One wire frame: length prefix + (header-length, JSON header,
    payload)."""
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    body_len = _HLEN.size + len(hdr) + len(payload)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"frame body of {body_len} bytes exceeds MAX_FRAME_BYTES "
            f"({MAX_FRAME_BYTES})")
    return _LEN.pack(body_len) + _HLEN.pack(len(hdr)) + hdr + payload


def unpack_body(body: bytes) -> tuple[dict, bytes]:
    """Split a frame body into (header dict, payload bytes)."""
    if len(body) < _HLEN.size:
        raise ProtocolError(f"frame body too short ({len(body)} bytes)")
    (hlen,) = _HLEN.unpack_from(body)
    if _HLEN.size + hlen > len(body):
        raise ProtocolError(
            f"header length {hlen} overruns frame body of {len(body)} bytes")
    try:
        header = json.loads(body[_HLEN.size:_HLEN.size + hlen])
    except ValueError as e:
        raise ProtocolError(f"frame header is not valid JSON: {e}") from e
    if not isinstance(header, dict):
        raise ProtocolError("frame header must be a JSON object")
    return header, body[_HLEN.size + hlen:]


def read_frame(recv_exactly) -> tuple[dict, bytes] | None:
    """Read one frame via ``recv_exactly(n) -> bytes`` (returns short or
    empty bytes at EOF). Returns None on clean EOF at a frame boundary;
    raises ``ProtocolError`` on truncation mid-frame or an oversized
    length prefix."""
    prefix = recv_exactly(_LEN.size)
    if not prefix:
        return None  # clean EOF between frames
    if len(prefix) < _LEN.size:
        raise ProtocolError("connection truncated inside a length prefix")
    (body_len,) = _LEN.unpack(prefix)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"length prefix {body_len} exceeds MAX_FRAME_BYTES "
            f"({MAX_FRAME_BYTES}); refusing to allocate")
    body = recv_exactly(body_len)
    if len(body) < body_len:
        raise ProtocolError(
            f"connection truncated inside a frame body "
            f"({len(body)}/{body_len} bytes)")
    return unpack_body(body)


def _sock_recv_exactly(sock: socket.socket):
    """A ``recv_exactly`` over a blocking socket (short read on EOF)."""

    def recv_exactly(n: int) -> bytes:
        chunks = []
        remaining = n
        while remaining:
            try:
                chunk = sock.recv(min(remaining, 1 << 20))
            except OSError:
                break  # peer reset / socket closed: surfaces as short read
            if not chunk:
                break
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)

    return recv_exactly


# ---------------------------------------------------------------------------
# message encoding


def encode_request(req_id: int, network: str, image, *, dtype=None,
                   deadline_ms=None, priority: int = 0) -> bytes:
    """A classify-request frame: the image travels as contiguous float32
    (exact for fp32/bf16/fp16 sources), options in the header."""
    arr = np.ascontiguousarray(np.asarray(image), dtype=np.float32)
    header = {
        "v": PROTOCOL_VERSION,
        "type": "classify",
        "id": int(req_id),
        "network": network,
        "shape": list(arr.shape),
        "image_dtype": "float32",
        "dtype": dtype,
        "deadline_ms": deadline_ms,
        "priority": int(priority),
    }
    return pack_frame(header, arr.tobytes())


def decode_request(header: dict, payload: bytes):
    """Validate a classify frame -> (network, image ndarray,
    RequestOptions). Raises ``BadRequest`` on anything malformed —
    the endpoint answers with a ``bad_request`` status, it never drops
    the connection for a well-framed bad request."""
    from repro.serving.request import RequestOptions

    if header.get("v") != PROTOCOL_VERSION:
        raise BadRequest(
            f"unsupported protocol version {header.get('v')!r} "
            f"(this server speaks v{PROTOCOL_VERSION})")
    if header.get("type") != "classify":
        raise BadRequest(f"unknown frame type {header.get('type')!r}")
    network = header.get("network")
    if not isinstance(network, str) or not network:
        raise BadRequest(f"missing or invalid network: {network!r}")
    if header.get("image_dtype") != "float32":
        raise BadRequest(
            f"image payload must be float32, got "
            f"{header.get('image_dtype')!r}")
    shape = header.get("shape")
    if (not isinstance(shape, list) or not shape
            or not all(isinstance(d, int) and d > 0 for d in shape)):
        raise BadRequest(f"invalid image shape: {shape!r}")
    expected = int(np.prod(shape)) * 4
    if expected != len(payload):
        raise BadRequest(
            f"payload is {len(payload)} bytes but shape {shape} needs "
            f"{expected}")
    image = np.frombuffer(payload, dtype=np.float32).reshape(shape)
    dtype = header.get("dtype")
    if dtype is not None and not isinstance(dtype, str):
        raise BadRequest(f"invalid dtype: {dtype!r}")
    deadline_ms = header.get("deadline_ms")
    if deadline_ms is not None and not isinstance(deadline_ms, (int, float)):
        raise BadRequest(f"invalid deadline_ms: {deadline_ms!r}")
    opts = RequestOptions(dtype=dtype, deadline_ms=deadline_ms,
                          priority=int(header.get("priority") or 0))
    return network, image, opts


def encode_response(req_id, *, logits=None, status: str = STATUS_OK,
                    message: str | None = None) -> bytes:
    """A result frame: logits as float32 payload on ok, a status code +
    message on error."""
    header = {
        "v": PROTOCOL_VERSION,
        "type": "result",
        "id": None if req_id is None else int(req_id),
        "status": status,
    }
    payload = b""
    if status == STATUS_OK:
        arr = np.ascontiguousarray(np.asarray(logits), dtype=np.float32)
        header["shape"] = list(arr.shape)
        payload = arr.tobytes()
    else:
        header["message"] = message or status
    return pack_frame(header, payload)


def decode_response(header: dict, payload: bytes):
    """-> (id, status, message, logits-or-None)."""
    if header.get("type") != "result":
        raise ProtocolError(f"expected a result frame, got "
                            f"{header.get('type')!r}")
    status = header.get("status", STATUS_INTERNAL)
    if status == STATUS_OK:
        shape = header.get("shape") or []
        logits = np.frombuffer(payload, dtype=np.float32).reshape(shape)
        return header.get("id"), status, None, logits
    return header.get("id"), status, header.get("message", status), None


# ---------------------------------------------------------------------------
# the server endpoint


class ServerEndpoint:
    """A threaded socket front door around one ``Server``.

    Listens on ``(host, port)`` (port 0 = ephemeral; read ``.address``),
    accepts any number of connections, and per connection runs a reader
    thread: each classify frame becomes ``server.submit(...)`` and the
    resulting ``Ticket``'s done-callback writes the response frame — so a
    slow dispatch never blocks the reader, and responses interleave in
    completion order (the ``id`` field is how clients match them up).

    Typed rejections (``Overloaded``/``DeadlineExceeded``/``CircuitOpen``)
    and ``BadRequest`` decode errors become status responses on a live
    connection. A framing violation or client disconnect closes the
    connection and **cancels every in-flight ticket** for it — a vanished
    client's queued requests shed at dequeue instead of computing logits
    nobody will read (the wire-level chaos test pins this).
    """

    def __init__(self, server, host: str = "127.0.0.1", port: int = 0):
        self.server = server
        self._sock = socket.create_server((host, port))
        self._sock.settimeout(0.2)  # so the accept loop sees close()
        self.address = self._sock.getsockname()[:2]
        self._lock = threading.Lock()
        self._conns: set[socket.socket] = set()
        self._closed = False
        self._served = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"endpoint-accept-{self.address[1]}")
        self._accept_thread.start()

    # ------------------------------------------------------------------

    def _accept_loop(self) -> None:
        while True:
            with self._lock:
                if self._closed:
                    return
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed under us
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True,
                             name="endpoint-conn").start()

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.settimeout(None)
        recv_exactly = _sock_recv_exactly(conn)
        write_lock = threading.Lock()  # done-callbacks fire concurrently
        inflight: dict[int, object] = {}  # req id -> Ticket
        alive = [True]

        def send(frame: bytes) -> None:
            with write_lock:
                if not alive[0]:
                    return  # connection torn down: drop the response
                try:
                    conn.sendall(frame)
                except OSError:
                    alive[0] = False

        def on_done(req_id):
            def callback(ticket):
                with self._lock:
                    self._served += 1
                inflight.pop(req_id, None)
                exc = ticket.exception()
                if exc is None:
                    send(encode_response(req_id,
                                         logits=ticket.result()))
                else:
                    send(encode_response(req_id, status=status_for(exc),
                                         message=str(exc)))
            return callback

        try:
            while True:
                try:
                    frame = read_frame(recv_exactly)
                except ProtocolError:
                    break  # unrecoverable stream: tear down
                if frame is None:
                    break  # clean EOF
                header, payload = frame
                req_id = header.get("id")
                try:
                    network, image, opts = decode_request(header, payload)
                    ticket = self.server.submit(network, image, options=opts)
                except (BadRequest, KeyError, ValueError) as e:
                    # unknown network raises KeyError from configs.get;
                    # both are the client's fault: answer, keep the conn
                    send(encode_response(req_id, status=STATUS_BAD_REQUEST,
                                         message=str(e)))
                    continue
                except Rejected as e:  # typed shed at admission
                    send(encode_response(req_id, status=status_for(e),
                                         message=str(e)))
                    continue
                except Exception as e:  # noqa: BLE001 - reported, not eaten
                    send(encode_response(req_id, status=STATUS_INTERNAL,
                                         message=str(e)))
                    continue
                inflight[req_id] = ticket
                ticket.add_done_callback(on_done(req_id))
        finally:
            with write_lock:
                alive[0] = False
            # a vanished client's queued work sheds at dequeue: cancel
            # every ticket still in flight for this connection
            for ticket in list(inflight.values()):
                ticket.cancel()
            with self._lock:
                self._conns.discard(conn)
            conn.close()

    # ------------------------------------------------------------------

    def close(self) -> None:
        """Stop accepting, close every live connection. Idempotent. The
        wrapped ``Server`` is NOT closed — the endpoint is a view onto
        it, not its owner."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()
        self._sock.close()
        self._accept_thread.join(5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        with self._lock:
            return {"address": list(self.address),
                    "connections": len(self._conns),
                    "served": self._served,
                    "closed": self._closed}
