"""Cross-network device scheduler — N request queues, one accelerator.

Every network a ``Server`` serves owns a ``MicroBatcher`` with its own
forming batch; before this module each batcher's loop thread dispatched
straight onto the device, so the device-order across networks was
whatever the OS thread scheduler produced — a slow or cold network's
dispatches could land back-to-back and head-of-line block a fast one.

``DeviceScheduler`` serializes all dispatch onto one device-owner thread
and makes the interleaving policy explicit: jobs are ordered
**oldest-deadline-first across networks** (a request's deadline when the
batcher enforces one, its arrival otherwise — so deadline-free traffic
degrades to global FIFO), with ``priority`` (from ``RequestOptions``) as
the coarse tier above the time key. Each batcher blocks on at most one
in-flight job, so a network can never have more than one dispatch queued
on the device: however deep a slow network's *request* queue grows, a
fast network's next batch waits behind at most ``N - 1`` other networks'
single dispatches — the fairness bound ``tests/test_frontdoor.py`` pins.

The scheduler is non-preemptive (a running dispatch finishes; the paper's
single-image kernels are short) and intentionally dumb about devices: one
scheduler == one accelerator. Streaming sessions keep their own leases
and threads (cross-stream device scheduling is a roadmap item).
"""
from __future__ import annotations

import heapq
import itertools
import threading


class _Job:
    """One queued dispatch: the thunk, its ordering key, and a settled
    flag the submitting batcher blocks on."""

    __slots__ = ("fn", "network", "done", "result", "error")

    def __init__(self, fn, network):
        self.fn = fn
        self.network = network
        self.done = threading.Event()
        self.result = None
        self.error: BaseException | None = None


class DeviceScheduler:
    """Fair dispatch interleaving for one accelerator.

    ``run(fn, urgency=...)`` enqueues ``fn`` and blocks until the device
    thread executed it, returning its value (or re-raising its error in
    the caller — batcher retry/breaker logic is inside ``fn``, so the
    scheduler never interprets failures, it only orders work).
    """

    def __init__(self, name: str = "device0"):
        self.name = name
        self._cond = threading.Condition()
        self._heap: list[tuple[tuple, int, _Job]] = []
        self._seq = itertools.count()  # FIFO tie-break inside one key
        self._closed = False
        self._completed: dict[str, int] = {}  # network -> jobs finished
        self._depth_high_water = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"device-scheduler-{name}")
        self._thread.start()

    # ------------------------------------------------------------------

    def run(self, fn, *, urgency: float, priority: int = 0,
            network: str | None = None):
        """Execute ``fn`` on the device thread; blocks until done.

        ``urgency`` is the time key (absolute ``perf_counter`` value —
        a deadline or an arrival; smaller dispatches first). ``priority``
        sorts above it: a higher-priority job beats any lower-priority
        one regardless of age.
        """
        job = _Job(fn, network or "?")
        with self._cond:
            if self._closed:
                raise RuntimeError(
                    f"device scheduler {self.name!r} is closed")
            heapq.heappush(self._heap, ((-priority, urgency),
                                        next(self._seq), job))
            self._depth_high_water = max(self._depth_high_water,
                                         len(self._heap))
            self._cond.notify()
        job.done.wait()
        if job.error is not None:
            raise job.error
        return job.result

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._heap and not self._closed:
                    self._cond.wait()
                if not self._heap and self._closed:
                    return
                _key, _seq, job = heapq.heappop(self._heap)
            try:
                job.result = job.fn()
            except BaseException as e:  # noqa: BLE001 - relayed, not eaten
                job.error = e
            with self._cond:
                self._completed[job.network] = \
                    self._completed.get(job.network, 0) + 1
            job.done.set()

    # ------------------------------------------------------------------

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain queued jobs, then stop the device thread. Idempotent.
        Close batchers first: a ``run`` racing ``close`` either lands in
        the drain or gets the typed closed error."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> dict:
        with self._cond:
            return {"device": self.name,
                    "queued": len(self._heap),
                    "depth_high_water": self._depth_high_water,
                    "completed": dict(sorted(self._completed.items())),
                    "jobs": sum(self._completed.values())}
