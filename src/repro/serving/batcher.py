"""Micro-batcher: coalesce concurrent single-image requests into one
padded-batch dispatch within a deadline window — and survive overload.

The paper's premise is batch-1 requests arriving one at a time; under
concurrent traffic the device still prefers one dispatch over N. The
batcher holds the first request of a batch for at most ``window_ms``,
coalescing whatever else arrives (up to ``max_batch``), then dispatches:

  * **batch == 1** — the single-image fast path: ``engine.run(image)``,
    exactly the paper's tuned per-layer dispatch, zero batching overhead;
  * **batch > 1**  — one ``engine.run_batch`` call on the stacked images,
    padded up to a power-of-two bucket (re-using the last image as filler)
    so a ragged final micro-batch doesn't cost a fresh jit trace for every
    distinct batch size.

``run_batch`` maps the *single-image* computation over the batch inside
one jitted call (``lax.map``), so outputs are bitwise-equal to sequential
``engine.run`` calls — micro-batching changes scheduling, never numerics.

Overload and failure handling (see docs/serving.md "Overload & failure
semantics"):

  * **admission control** — ``max_queue`` bounds the queue; a submit
    beyond it is rejected *immediately* with ``Overloaded`` (typed, cheap,
    before any work). A closed batcher rejects the same way.
  * **deadline shedding** — with ``deadline_ms`` set, a request still
    queued past its deadline (or cancelled by its client) is shed **at
    dequeue** with ``DeadlineExceeded``: an expired request never burns a
    dispatch, which is what keeps an overloaded queue from doing work
    nobody is waiting for.
  * **retry + breaker** — a dispatch raising ``TransientFailure`` (the
    repo-wide transient-error type) is retried with capped exponential
    backoff (``retry``); *every* dispatch failure feeds the per-engine
    ``CircuitBreaker``, which trips open after N consecutive failures so
    a sick engine sheds fast (``CircuitOpen``) instead of queueing.
  * **degraded mode** — when the breaker trips and a ``degrade`` hook was
    provided (the server wires ``EngineCache.degrade``), the batcher swaps
    its engine for the xla-only fallback, resets the breaker, and retries
    the in-flight batch there — serving continues at reduced speed rather
    than going dark.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp

from repro.serving import request as req_mod
from repro.serving.request import Request
from repro.serving.resilience import (
    CircuitBreaker,
    CircuitOpen,
    DeadlineExceeded,
    Overloaded,
    RetryPolicy,
    TransientFailure,
)

_STOP = object()


def bucket(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch — the padded batch
    size. Bounds the set of traced batch shapes to O(log max_batch)."""
    assert 1 <= n <= max_batch
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


class MicroBatcher:
    """One request loop around one engine.

    ``submit`` is non-blocking and returns a Future; a daemon thread owns
    the engine and is the only place dispatch happens, so callers never
    contend on the device.
    """

    def __init__(self, engine, *, max_batch: int = 8, window_ms: float = 2.0,
                 pad_batches: bool = True, deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 retry: RetryPolicy | None = None,
                 breaker: CircuitBreaker | None = None,
                 degrade=None, faults=None):
        assert max_batch >= 1
        self.engine = engine
        # power-of-two invariant: bucket() pads to powers of two, so a
        # non-power-of-two cap would add one extra traced batch shape
        # (the clipped max_batch itself); round down at construction so
        # the traced-shape set stays exactly {1, 2, 4, ..., max_batch}
        self.max_batch = 1 << (max_batch.bit_length() - 1)
        self.window_s = window_ms / 1e3
        # per-request latency SLO (submit -> resolution). Besides the
        # miss telemetry, it is the shed deadline: a request still queued
        # past arrival + deadline is failed at dequeue, before compute.
        self.deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        # admission bound: queued (admitted, not yet dequeued) requests
        # beyond this are rejected with Overloaded. None = unbounded.
        self.max_queue = max_queue
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._degrade = degrade      # () -> replacement engine, or None
        self._faults = faults        # FaultInjector, or None
        self.pad_batches = pad_batches
        self.dispatches: list[dict] = []  # {batch, padded, latencies}
        # the loop thread appends to the dispatch log while stats() reads
        # it from caller threads: every access goes through this lock
        self._stats_lock = threading.Lock()
        self._causes = {"full": 0, "window": 0, "drain": 0}
        self._shed = {"overload": 0, "deadline": 0, "cancelled": 0,
                      "breaker": 0}
        self._retries = 0
        self.degraded = 0            # engine swaps to the xla fallback
        self._queue: queue.Queue = queue.Queue()
        # _admit_lock makes (closed-check + depth-check + enqueue) atomic
        # against close() and against racing submitters, so the admission
        # bound is exact and nothing enqueues behind the stop sentinel
        self._admit_lock = threading.Lock()
        self._depth = 0
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"microbatcher-{id(self):x}")
        self._thread.start()

    # ------------------------------------------------------------------

    def submit(self, image) -> Future:
        """Enqueue one (H, W, C) image; the Future resolves to (classes,)
        logits. Raises ``Overloaded`` if the batcher is closed or the
        bounded queue is full (admission control — shed before work)."""
        return self.submit_request(image).future

    def submit_request(self, image) -> Request:
        """Like ``submit`` but returns the ``Request`` record, so callers
        (``Server.run``) can ``cancel()`` it on their own timeout."""
        req = Request(image)
        if self.deadline_s is not None:
            req.deadline = req.arrival + self.deadline_s
        with self._admit_lock:
            if self._closed:
                raise Overloaded("batcher is closed")
            if self.max_queue is not None and self._depth >= self.max_queue:
                with self._stats_lock:
                    self._shed["overload"] += 1
                raise Overloaded(
                    f"queue full ({self._depth}/{self.max_queue} waiting); "
                    f"request shed at admission")
            self._depth += 1
            self._queue.put(req)
        return req

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain the queue, dispatch what's pending, stop the thread.
        Idempotent; racing submits either land before the stop sentinel
        (and drain) or are rejected with ``Overloaded``."""
        with self._admit_lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(_STOP)
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------

    def _take(self, req: Request) -> bool:
        """Dequeue-side bookkeeping + shedding: returns True if ``req``
        should join the batch, False if it was shed (expired/cancelled)
        before any compute was spent on it."""
        with self._admit_lock:
            self._depth -= 1
        now = time.perf_counter()
        if req.cancelled:
            with self._stats_lock:
                self._shed["cancelled"] += 1
            req_mod.fail(req, DeadlineExceeded(
                f"request {req.id} cancelled by its client; shed at dequeue"))
            return False
        if req.expired(now):
            with self._stats_lock:
                self._shed["deadline"] += 1
            req_mod.fail(req, DeadlineExceeded(
                f"request {req.id} missed its {self.deadline_s * 1e3:g}ms "
                f"deadline while queued; shed at dequeue"))
            return False
        return True

    def _loop(self) -> None:
        stopping = False
        while not stopping:
            req = self._queue.get()  # block until traffic (or shutdown)
            if req is _STOP:
                break
            if not self._take(req):
                continue  # shed at dequeue: never starts a batch
            batch = [req]
            deadline = time.perf_counter() + self.window_s
            while len(batch) < self.max_batch:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=wait)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stopping = True
                    break
                if self._take(nxt):
                    batch.append(nxt)
            cause = ("drain" if stopping
                     else "full" if len(batch) >= self.max_batch
                     else "window")
            with self._stats_lock:
                self._causes[cause] += 1
            self._dispatch(batch)
        # a submit racing close() can enqueue behind the _STOP sentinel;
        # fail those requests instead of leaving their futures unresolved
        # (same typed rejection as admission-control shedding)
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not _STOP:
                with self._admit_lock:
                    self._depth -= 1
                req_mod.fail(req, Overloaded("batcher closed"))

    # ------------------------------------------------------------------
    # dispatch with retry / breaker / degraded-mode fallback

    def _run(self, batch: list[Request]):
        if len(batch) == 1:
            # the paper's single-image fast path: tuned per-layer
            # dispatch on exactly one image, no stacking, no padding
            outs = [self.engine.run(batch[0].image)]
            padded = 1
        else:
            n = len(batch)
            padded = bucket(n, self.max_batch) if self.pad_batches else n
            images = [r.image for r in batch]
            images += [images[-1]] * (padded - n)  # filler rows
            logits = self.engine.run_batch(jnp.stack(images))
            outs = [logits[i] for i in range(n)]
        # settle async dispatch before resolving: futures hand back
        # finished results, and latency stamps include the compute
        return jax.block_until_ready(outs), padded

    def _try_degrade(self) -> bool:
        """Swap in the degraded (xla-only) engine via the owner's hook.
        One swap per batcher: if the fallback is *also* failing, the
        breaker stays open and sheds instead of thrashing rebuilds."""
        if self._degrade is None or self.degraded:
            return False
        try:
            engine = self._degrade()
        except Exception:
            return False  # degrade itself failed: stay open, shed fast
        self.engine = engine
        with self._stats_lock:
            self.degraded += 1
        self.breaker.reset()
        return True

    def _attempt(self, batch: list[Request]):
        """Run ``batch`` to completion under the resilience policy:
        transient failures retry with backoff, every failure feeds the
        breaker, a trip attempts the degraded-mode engine swap, and an
        open breaker sheds with ``CircuitOpen``."""
        attempt = 0
        while True:
            if not self.breaker.allow():
                if self._try_degrade():
                    continue
                with self._stats_lock:
                    self._shed["breaker"] += len(batch)
                raise CircuitOpen(
                    f"engine circuit breaker is {self.breaker.state} "
                    f"after {self.breaker.threshold} consecutive failures; "
                    f"shedding until it recovers")
            try:
                # injected dispatch faults model a sick tuned kernel, so
                # a degraded (xla-only) engine no longer contains them
                if self._faults is not None and not self.degraded:
                    delay = self._faults.check("dispatch")
                    if delay:
                        time.sleep(delay)
                outs, padded = self._run(batch)
            except Exception as e:
                tripped = self.breaker.record_failure()
                if tripped and self._try_degrade():
                    continue  # serve this very batch from the fallback
                if isinstance(e, TransientFailure) \
                        and attempt < self.retry.max_retries \
                        and self.breaker.allow():
                    with self._stats_lock:
                        self._retries += 1
                    time.sleep(self.retry.delay(attempt))
                    attempt += 1
                    continue
                raise
            self.breaker.record_success()
            return outs, padded

    def _dispatch(self, batch: list[Request]) -> None:
        try:
            outs, padded = self._attempt(batch)
        except Exception as e:  # resolve, don't kill the loop
            for r in batch:
                req_mod.fail(r, e)
            return
        for r, o in zip(batch, outs):
            req_mod.resolve(r, o)
        with self._stats_lock:
            self.dispatches.append({
                "batch": len(batch),
                "padded": padded,
                "latencies": [r.latency for r in batch],
            })

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Dispatch-log aggregates: request count, batch-size histogram,
        latency mean/p50/p95/max (seconds, submit -> future resolution),
        live queue depth, dispatch causes (full batch vs expired window
        vs shutdown drain), deadline misses if an SLO is set, and the
        resilience counters (sheds by cause, retries, breaker state,
        degraded-mode swaps)."""
        with self._stats_lock:  # snapshot: the loop thread appends live
            dispatches = list(self.dispatches)
            causes = dict(self._causes)
            shed = dict(self._shed)
            retries = self._retries
            degraded = self.degraded
        lats = sorted(l for d in dispatches for l in d["latencies"])

        def pct(q):
            if not lats:
                return None
            return lats[min(len(lats) - 1, round(q / 100 * (len(lats) - 1)))]

        hist: dict[int, int] = {}
        for d in dispatches:
            hist[d["batch"]] = hist.get(d["batch"], 0) + 1
        misses = (None if self.deadline_s is None
                  else sum(1 for l in lats if l > self.deadline_s))
        return {
            "requests": len(lats),
            "dispatches": len(dispatches),
            "queue_depth": self._queue.qsize(),
            "max_queue": self.max_queue,
            "window_ms": self.window_s * 1e3,
            "dispatch_causes": causes,
            "batch_histogram": dict(sorted(hist.items())),
            "shed": shed,
            "shed_total": sum(shed.values()),
            "retries": retries,
            "breaker": self.breaker.stats(),
            "degraded": degraded,
            "deadline_ms": (None if self.deadline_s is None
                            else self.deadline_s * 1e3),
            "deadline_misses": misses,
            "deadline_miss_rate": (None if misses is None or not lats
                                   else misses / len(lats)),
            "latency_mean_s": sum(lats) / len(lats) if lats else None,
            "latency_p50_s": pct(50),
            "latency_p95_s": pct(95),
            "latency_max_s": max(lats) if lats else None,
        }
