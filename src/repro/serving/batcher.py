"""Micro-batcher: coalesce concurrent single-image requests into one
padded-batch dispatch within a deadline window.

The paper's premise is batch-1 requests arriving one at a time; under
concurrent traffic the device still prefers one dispatch over N. The
batcher holds the first request of a batch for at most ``window_ms``,
coalescing whatever else arrives (up to ``max_batch``), then dispatches:

  * **batch == 1** — the single-image fast path: ``engine.run(image)``,
    exactly the paper's tuned per-layer dispatch, zero batching overhead;
  * **batch > 1**  — one ``engine.run_batch`` call on the stacked images,
    padded up to a power-of-two bucket (re-using the last image as filler)
    so a ragged final micro-batch doesn't cost a fresh jit trace for every
    distinct batch size.

``run_batch`` maps the *single-image* computation over the batch inside
one jitted call (``lax.map``), so outputs are bitwise-equal to sequential
``engine.run`` calls — micro-batching changes scheduling, never numerics.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future

import jax
import jax.numpy as jnp

from repro.serving import request as req_mod
from repro.serving.request import Request

_STOP = object()


def bucket(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch — the padded batch
    size. Bounds the set of traced batch shapes to O(log max_batch)."""
    assert 1 <= n <= max_batch
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


class MicroBatcher:
    """One request loop around one engine.

    ``submit`` is non-blocking and returns a Future; a daemon thread owns
    the engine and is the only place dispatch happens, so callers never
    contend on the device.
    """

    def __init__(self, engine, *, max_batch: int = 8, window_ms: float = 2.0,
                 pad_batches: bool = True, deadline_ms: float | None = None):
        assert max_batch >= 1
        self.engine = engine
        # power-of-two invariant: bucket() pads to powers of two, so a
        # non-power-of-two cap would add one extra traced batch shape
        # (the clipped max_batch itself); round down at construction so
        # the traced-shape set stays exactly {1, 2, 4, ..., max_batch}
        self.max_batch = 1 << (max_batch.bit_length() - 1)
        self.window_s = window_ms / 1e3
        # per-request latency SLO (submit -> resolution); None = no SLO.
        # stats() reports misses against it — the same deadline telemetry
        # streaming sessions expose, for on-demand traffic.
        self.deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        self.pad_batches = pad_batches
        self.dispatches: list[dict] = []  # {batch, padded, latencies}
        # the loop thread appends to the dispatch log while stats() reads
        # it from caller threads: every access goes through this lock
        self._stats_lock = threading.Lock()
        self._causes = {"full": 0, "window": 0, "drain": 0}
        self._queue: queue.Queue = queue.Queue()
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"microbatcher-{id(self):x}")
        self._thread.start()

    # ------------------------------------------------------------------

    def submit(self, image) -> Future:
        """Enqueue one (H, W, C) image; the Future resolves to (classes,)
        logits."""
        if self._closed:
            raise RuntimeError("batcher is closed")
        req = Request(image)
        self._queue.put(req)
        return req.future

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain the queue, dispatch what's pending, stop the thread."""
        if self._closed:
            return
        self._closed = True
        self._queue.put(_STOP)
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------

    def _loop(self) -> None:
        import time

        stopping = False
        while not stopping:
            req = self._queue.get()  # block until traffic (or shutdown)
            if req is _STOP:
                break
            batch = [req]
            deadline = time.perf_counter() + self.window_s
            while len(batch) < self.max_batch:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=wait)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stopping = True
                    break
                batch.append(nxt)
            cause = ("drain" if stopping
                     else "full" if len(batch) >= self.max_batch
                     else "window")
            with self._stats_lock:
                self._causes[cause] += 1
            self._dispatch(batch)
        # a submit racing close() can enqueue behind the _STOP sentinel;
        # fail those requests instead of leaving their futures unresolved
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not _STOP:
                req_mod.fail(req, RuntimeError("batcher closed"))

    def _dispatch(self, batch: list[Request]) -> None:
        try:
            if len(batch) == 1:
                # the paper's single-image fast path: tuned per-layer
                # dispatch on exactly one image, no stacking, no padding
                outs = [self.engine.run(batch[0].image)]
            else:
                n = len(batch)
                padded = bucket(n, self.max_batch) if self.pad_batches else n
                images = [r.image for r in batch]
                images += [images[-1]] * (padded - n)  # filler rows
                logits = self.engine.run_batch(jnp.stack(images))
                outs = [logits[i] for i in range(n)]
            # settle async dispatch before resolving: futures hand back
            # finished results, and latency stamps include the compute
            outs = jax.block_until_ready(outs)
        except Exception as e:  # resolve, don't kill the loop
            for r in batch:
                req_mod.fail(r, e)
            return
        for r, o in zip(batch, outs):
            req_mod.resolve(r, o)
        with self._stats_lock:
            self.dispatches.append({
                "batch": len(batch),
                "padded": len(batch) if len(batch) == 1 else padded,
                "latencies": [r.latency for r in batch],
            })

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Dispatch-log aggregates: request count, batch-size histogram,
        latency mean/p50/p95/max (seconds, submit -> future resolution),
        live queue depth, dispatch causes (full batch vs expired window
        vs shutdown drain), and deadline misses if an SLO is set."""
        with self._stats_lock:  # snapshot: the loop thread appends live
            dispatches = list(self.dispatches)
            causes = dict(self._causes)
        lats = sorted(l for d in dispatches for l in d["latencies"])

        def pct(q):
            if not lats:
                return None
            return lats[min(len(lats) - 1, round(q / 100 * (len(lats) - 1)))]

        hist: dict[int, int] = {}
        for d in dispatches:
            hist[d["batch"]] = hist.get(d["batch"], 0) + 1
        misses = (None if self.deadline_s is None
                  else sum(1 for l in lats if l > self.deadline_s))
        return {
            "requests": len(lats),
            "dispatches": len(dispatches),
            "queue_depth": self._queue.qsize(),
            "window_ms": self.window_s * 1e3,
            "dispatch_causes": causes,
            "batch_histogram": dict(sorted(hist.items())),
            "deadline_ms": (None if self.deadline_s is None
                            else self.deadline_s * 1e3),
            "deadline_misses": misses,
            "deadline_miss_rate": (None if misses is None or not lats
                                   else misses / len(lats)),
            "latency_mean_s": sum(lats) / len(lats) if lats else None,
            "latency_p50_s": pct(50),
            "latency_p95_s": pct(95),
            "latency_max_s": max(lats) if lats else None,
        }
