"""Continuous micro-batching: coalesce concurrent single-image requests
into one padded-batch dispatch with mid-flight admission — and survive
overload.

The paper's premise is batch-1 requests arriving one at a time; under
concurrent traffic the device still prefers one dispatch over N. The
batcher keeps one **forming batch** (the pending deque): a new request is
admitted into it mid-flight — it joins the *next* dispatch whenever its
padded power-of-two shape still fits (fewer than ``max_batch`` requests
already formed), instead of waiting for a window of its own. The batch
goes to the device when it fills, or when the window measured from its
**oldest request's arrival** expires — so a request that queued up behind
a long dispatch goes out the moment the engine frees up, never paying a
fresh window on top of the wait (the continuous-batching property; the
deadline-window design it replaces restarted the window at dequeue).
Dispatch shape:

  * **batch == 1** — the single-image fast path: ``engine.run(image)``,
    exactly the paper's tuned per-layer dispatch, zero batching overhead;
  * **batch > 1**  — one ``engine.run_batch`` call on the stacked images,
    padded up to a power-of-two bucket (re-using the last image as filler)
    so a ragged final micro-batch doesn't cost a fresh jit trace for every
    distinct batch size.

``run_batch`` maps the *single-image* computation over the batch inside
one jitted call (``lax.map``), so outputs are bitwise-equal to sequential
``engine.run`` calls — micro-batching changes scheduling, never numerics,
and mid-flight admission changes only *when* a request dispatches, never
what its batch computes.

Every dispatch can be routed through a shared ``DeviceScheduler``
(``scheduler=``): the batcher's loop thread then submits the attempt as a
job and blocks while the device thread runs it under the cross-network
fairness policy — and because the loop thread is blocked *outside* the
admission lock, the next batch keeps forming mid-flight underneath it.

Overload and failure handling (see docs/serving.md "Overload & failure
semantics"):

  * **admission control** — ``max_queue`` bounds the pending deque; a
    submit beyond it is rejected *immediately* with ``Overloaded`` (typed,
    cheap, before any work). A closed batcher rejects the same way.
  * **deadline shedding** — with ``deadline_ms`` set (per-batcher default
    or per-request override), a request still queued past its deadline
    (or cancelled by its client) is shed **at dequeue** with
    ``DeadlineExceeded``: an expired request never burns a dispatch,
    which is what keeps an overloaded queue from doing work nobody is
    waiting for.
  * **retry + breaker** — a dispatch raising ``TransientFailure`` (the
    repo-wide transient-error type) is retried with capped exponential
    backoff (``retry``); *every* dispatch failure feeds the per-engine
    ``CircuitBreaker``, which trips open after N consecutive failures so
    a sick engine sheds fast (``CircuitOpen``) instead of queueing.
  * **degraded mode** — when the breaker trips and a ``degrade`` hook was
    provided (the server wires ``EngineCache.degrade``), the batcher swaps
    its engine for the xla-only fallback, resets the breaker, and retries
    the in-flight batch there — serving continues at reduced speed rather
    than going dark.
"""
from __future__ import annotations

import threading
import time
from collections import deque

import jax
import jax.numpy as jnp

from repro.serving import request as req_mod
from repro.serving.request import Request, Ticket
from repro.serving.resilience import (
    CircuitBreaker,
    CircuitOpen,
    DeadlineExceeded,
    Overloaded,
    RetryPolicy,
    TransientFailure,
)


def bucket(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch — the padded batch
    size. Bounds the set of traced batch shapes to O(log max_batch)."""
    assert 1 <= n <= max_batch
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


class MicroBatcher:
    """One request loop around one engine.

    ``submit`` is non-blocking and returns a ``Ticket``; a daemon thread
    owns batch formation, and dispatch happens either on that thread or —
    with ``scheduler=`` — on the shared device thread under the
    cross-network fairness policy, so callers never contend on the device.
    """

    def __init__(self, engine, *, max_batch: int = 8, window_ms: float = 2.0,
                 pad_batches: bool = True, deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 retry: RetryPolicy | None = None,
                 breaker: CircuitBreaker | None = None,
                 degrade=None, faults=None, scheduler=None,
                 name: str | None = None):
        assert max_batch >= 1
        self.engine = engine
        self.name = name if name is not None else f"batcher-{id(self):x}"
        # power-of-two invariant: bucket() pads to powers of two, so a
        # non-power-of-two cap would add one extra traced batch shape
        # (the clipped max_batch itself); round down at construction so
        # the traced-shape set stays exactly {1, 2, 4, ..., max_batch}
        self.max_batch = 1 << (max_batch.bit_length() - 1)
        self.window_s = window_ms / 1e3
        # per-request latency SLO (submit -> resolution). Besides the
        # miss telemetry, it is the shed deadline: a request still queued
        # past arrival + deadline is failed at dequeue, before compute.
        self.deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        # admission bound: pending (admitted, not yet dequeued) requests
        # beyond this are rejected with Overloaded. None = unbounded.
        self.max_queue = max_queue
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._degrade = degrade      # () -> replacement engine, or None
        self._faults = faults        # FaultInjector, or None
        self._scheduler = scheduler  # DeviceScheduler, or None (inline)
        self.pad_batches = pad_batches
        self.dispatches: list[dict] = []  # {batch, padded, latencies}
        # the dispatch path appends to the dispatch log while stats()
        # reads it from caller threads: every access takes this lock
        self._stats_lock = threading.Lock()
        self._causes = {"full": 0, "window": 0, "drain": 0}
        self._shed = {"overload": 0, "deadline": 0, "cancelled": 0,
                      "breaker": 0}
        self._retries = 0
        self._joined = 0             # mid-flight admissions into a
        #                              forming batch (pending was nonempty)
        self.degraded = 0            # engine swaps to the xla fallback
        # _cond guards the forming batch: (closed-check + depth-check +
        # append) is atomic against close() and racing submitters, so the
        # admission bound is exact; the loop thread is the only consumer.
        self._cond = threading.Condition()
        self._pending: deque[Request] = deque()
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"microbatcher-{id(self):x}")
        self._thread.start()

    # ------------------------------------------------------------------

    def submit(self, image) -> Ticket:
        """Enqueue one (H, W, C) image; the Ticket resolves to (classes,)
        logits. Raises ``Overloaded`` if the batcher is closed or the
        bounded queue is full (admission control — shed before work)."""
        return Ticket(self.submit_request(image))

    def submit_request(self, image, *, deadline_ms: float | None = None,
                       priority: int = 0) -> Request:
        """Like ``submit`` but returns the ``Request`` record, so owners
        (``Server``) can wrap it themselves. ``deadline_ms`` overrides
        the batcher-wide shed deadline for this request; ``priority``
        rides to the device scheduler's ordering key."""
        req = Request(image, priority=priority)
        deadline_s = (self.deadline_s if deadline_ms is None
                      else deadline_ms / 1e3)
        if deadline_s is not None:
            req.deadline = req.arrival + deadline_s
        with self._cond:
            if self._closed:
                raise Overloaded("batcher is closed")
            if self.max_queue is not None \
                    and len(self._pending) >= self.max_queue:
                with self._stats_lock:
                    self._shed["overload"] += 1
                raise Overloaded(
                    f"queue full ({len(self._pending)}/{self.max_queue} "
                    f"waiting); request shed at admission")
            if self._pending:  # mid-flight: joins the forming batch
                self._joined += 1
            self._pending.append(req)
            self._cond.notify()
        return req

    def close(self, timeout: float | None = 30.0) -> None:
        """Flush the forming batch, dispatch what's pending, stop the
        thread. Idempotent; racing submits either land before the closed
        flag flips (and drain) or are rejected with ``Overloaded``."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------

    def _take(self, req: Request) -> bool:
        """Dequeue-side shedding: returns True if ``req`` should join the
        dispatch, False if it was shed (expired/cancelled) before any
        compute was spent on it."""
        now = time.perf_counter()
        if req.cancelled:
            with self._stats_lock:
                self._shed["cancelled"] += 1
            req_mod.fail(req, DeadlineExceeded(
                f"request {req.id} cancelled by its client; shed at dequeue"))
            return False
        if req.expired(now):
            budget = (req.deadline - req.arrival) * 1e3
            with self._stats_lock:
                self._shed["deadline"] += 1
            req_mod.fail(req, DeadlineExceeded(
                f"request {req.id} missed its {budget:g}ms deadline while "
                f"queued; shed at dequeue"))
            return False
        return True

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending:  # closed and drained: exit
                    return
                # the batching window is anchored at the OLDEST pending
                # request's arrival — a batch that formed while the
                # previous dispatch held the device goes out immediately
                window_end = self._pending[0].arrival + self.window_s
                while len(self._pending) < self.max_batch \
                        and not self._closed:
                    wait = window_end - time.perf_counter()
                    if wait <= 0:
                        break
                    self._cond.wait(wait)
                take = min(len(self._pending), self.max_batch)
                raw = [self._pending.popleft() for _ in range(take)]
                drain = self._closed
            batch = [r for r in raw if self._take(r)]
            if not batch:
                continue  # everything shed at dequeue: no dispatch
            cause = ("drain" if drain
                     else "full" if len(raw) >= self.max_batch
                     else "window")
            with self._stats_lock:
                self._causes[cause] += 1
            self._dispatch(batch)

    # ------------------------------------------------------------------
    # dispatch with retry / breaker / degraded-mode fallback

    def _run(self, batch: list[Request]):
        if len(batch) == 1:
            # the paper's single-image fast path: tuned per-layer
            # dispatch on exactly one image, no stacking, no padding
            outs = [self.engine.run(batch[0].image)]
            padded = 1
        else:
            n = len(batch)
            padded = bucket(n, self.max_batch) if self.pad_batches else n
            images = [r.image for r in batch]
            images += [images[-1]] * (padded - n)  # filler rows
            logits = self.engine.run_batch(jnp.stack(images))
            outs = [logits[i] for i in range(n)]
        # settle async dispatch before resolving: futures hand back
        # finished results, and latency stamps include the compute
        return jax.block_until_ready(outs), padded

    def _try_degrade(self) -> bool:
        """Swap in the degraded (xla-only) engine via the owner's hook.
        One swap per batcher: if the fallback is *also* failing, the
        breaker stays open and sheds instead of thrashing rebuilds."""
        if self._degrade is None or self.degraded:
            return False
        try:
            engine = self._degrade()
        except Exception:
            return False  # degrade itself failed: stay open, shed fast
        self.engine = engine
        with self._stats_lock:
            self.degraded += 1
        self.breaker.reset()
        return True

    def _attempt(self, batch: list[Request]):
        """Run ``batch`` to completion under the resilience policy:
        transient failures retry with backoff, every failure feeds the
        breaker, a trip attempts the degraded-mode engine swap, and an
        open breaker sheds with ``CircuitOpen``."""
        attempt = 0
        while True:
            if not self.breaker.allow():
                if self._try_degrade():
                    continue
                with self._stats_lock:
                    self._shed["breaker"] += len(batch)
                raise CircuitOpen(
                    f"engine circuit breaker is {self.breaker.state} "
                    f"after {self.breaker.threshold} consecutive failures; "
                    f"shedding until it recovers")
            try:
                # injected dispatch faults model a sick tuned kernel, so
                # a degraded (xla-only) engine no longer contains them
                if self._faults is not None and not self.degraded:
                    delay = self._faults.check("dispatch")
                    if delay:
                        time.sleep(delay)
                outs, padded = self._run(batch)
            except Exception as e:
                tripped = self.breaker.record_failure()
                if tripped and self._try_degrade():
                    continue  # serve this very batch from the fallback
                if isinstance(e, TransientFailure) \
                        and attempt < self.retry.max_retries \
                        and self.breaker.allow():
                    with self._stats_lock:
                        self._retries += 1
                    time.sleep(self.retry.delay(attempt))
                    attempt += 1
                    continue
                raise
            self.breaker.record_success()
            return outs, padded

    def _dispatch(self, batch: list[Request]) -> None:
        try:
            if self._scheduler is not None:
                # the shared device thread runs the attempt under the
                # cross-network fairness policy; this loop thread blocks
                # here while the NEXT batch keeps forming via submit()
                outs, padded = self._scheduler.run(
                    lambda: self._attempt(batch),
                    urgency=min(r.urgency for r in batch),
                    priority=max(r.priority for r in batch),
                    network=self.name)
            else:
                outs, padded = self._attempt(batch)
        except Exception as e:  # resolve, don't kill the loop
            for r in batch:
                req_mod.fail(r, e)
            return
        for r, o in zip(batch, outs):
            req_mod.resolve(r, o)
        with self._stats_lock:
            self.dispatches.append({
                "batch": len(batch),
                "padded": padded,
                "latencies": [r.latency for r in batch],
            })

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Dispatch-log aggregates: request count, batch-size histogram,
        latency mean/p50/p95/max (seconds, submit -> future resolution),
        live queue depth, mid-flight joins, dispatch causes (full batch
        vs expired window vs shutdown drain), deadline misses if an SLO
        is set, and the resilience counters (sheds by cause, retries,
        breaker state, degraded-mode swaps)."""
        with self._cond:
            depth = len(self._pending)
            joined = self._joined
        with self._stats_lock:  # snapshot: the dispatch path appends live
            dispatches = list(self.dispatches)
            causes = dict(self._causes)
            shed = dict(self._shed)
            retries = self._retries
            degraded = self.degraded
        lats = sorted(l for d in dispatches for l in d["latencies"])

        def pct(q):
            if not lats:
                return None
            return lats[min(len(lats) - 1, round(q / 100 * (len(lats) - 1)))]

        hist: dict[int, int] = {}
        for d in dispatches:
            hist[d["batch"]] = hist.get(d["batch"], 0) + 1
        misses = (None if self.deadline_s is None
                  else sum(1 for l in lats if l > self.deadline_s))
        return {
            "requests": len(lats),
            "dispatches": len(dispatches),
            "queue_depth": depth,
            "max_queue": self.max_queue,
            "window_ms": self.window_s * 1e3,
            "joined_forming": joined,
            "dispatch_causes": causes,
            "batch_histogram": dict(sorted(hist.items())),
            "shed": shed,
            "shed_total": sum(shed.values()),
            "retries": retries,
            "breaker": self.breaker.stats(),
            "degraded": degraded,
            "deadline_ms": (None if self.deadline_s is None
                            else self.deadline_s * 1e3),
            "deadline_misses": misses,
            "deadline_miss_rate": (None if misses is None or not lats
                                   else misses / len(lats)),
            "latency_mean_s": sum(lats) / len(lats) if lats else None,
            "latency_p50_s": pct(50),
            "latency_p95_s": pct(95),
            "latency_max_s": max(lats) if lats else None,
        }
