"""Serving subsystem — the request loop above ``InferenceEngine``.

``Server`` accepts single-image requests for many networks out of one
process; ``MicroBatcher`` coalesces concurrent requests into one
padded-batch dispatch with **mid-flight admission** (a new request joins
the forming batch whenever its padded power-of-two shape still fits;
batch-1 traffic keeps the paper's single-image fast path); a shared
``DeviceScheduler`` interleaves every network's dispatches onto the
accelerator oldest-deadline-first, so a slow network cannot head-of-line
block a fast one. ``EngineCache`` LRU-caches built engines keyed by
(network, input_size, device, dtype) and reuses tuned plans across
variants; ``StreamSession`` (``Server.open_stream``) serves fixed-rate
frame streams over per-stream engine leases.

The wire tier puts a socket in front of the same surface:
``ServerEndpoint`` speaks a length-prefixed binary framing
(``protocol.py``), ``AsyncClient`` is the asyncio caller —
``await client.classify(net, image)`` returns logits bitwise-equal to
``engine.run``, and typed rejections re-raise client-side.

Public API: configure with frozen ``ServingOptions`` (server-wide) and
``RequestOptions`` (per call); every submit path returns a ``Ticket``
(``.result(timeout)`` / ``.cancel()`` / ``.done()`` + latency stamps).
The typed-exception hierarchy (``Rejected`` > ``Overloaded`` /
``DeadlineExceeded`` / ``CircuitOpen``, plus the wire-tier
``ProtocolError`` / ``BadRequest`` / ``RemoteError``) is exported here —
clients never import from ``resilience``/``request`` internals. See
docs/serving.md ("Front door", "Overload & failure semantics").
"""
from repro.serving.batcher import MicroBatcher, bucket  # noqa: F401
from repro.serving.client import AsyncClient  # noqa: F401
from repro.serving.engine_cache import (  # noqa: F401
    EngineCache,
    EngineLease,
    engine_key,
    plan_key,
    xla_fallback_plan,
)
from repro.serving.faults import Fault, FaultInjector  # noqa: F401
from repro.serving.protocol import (  # noqa: F401
    MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
    BadRequest,
    ProtocolError,
    RemoteError,
    ServerEndpoint,
    decode_request,
    decode_response,
    encode_request,
    encode_response,
    pack_frame,
    read_frame,
    unpack_body,
)
from repro.serving.request import (  # noqa: F401
    Request,
    RequestOptions,
    Ticket,
)
from repro.serving.resilience import (  # noqa: F401
    CircuitBreaker,
    CircuitOpen,
    DeadlineExceeded,
    Overloaded,
    Rejected,
    RetryPolicy,
    TransientFailure,
)
from repro.serving.scheduler import DeviceScheduler  # noqa: F401
from repro.serving.server import Server, ServingOptions  # noqa: F401
from repro.serving.streaming import (  # noqa: F401
    Frame,
    FrameDropped,
    StreamScheduler,
    StreamSession,
)

__all__ = [
    "AsyncClient",
    "BadRequest",
    "CircuitBreaker",
    "CircuitOpen",
    "DeadlineExceeded",
    "DeviceScheduler",
    "EngineCache",
    "EngineLease",
    "Fault",
    "FaultInjector",
    "Frame",
    "FrameDropped",
    "MAX_FRAME_BYTES",
    "MicroBatcher",
    "Overloaded",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "Rejected",
    "RemoteError",
    "Request",
    "RequestOptions",
    "RetryPolicy",
    "Server",
    "ServerEndpoint",
    "ServingOptions",
    "StreamScheduler",
    "StreamSession",
    "Ticket",
    "TransientFailure",
    "bucket",
    "decode_request",
    "decode_response",
    "encode_request",
    "encode_response",
    "engine_key",
    "pack_frame",
    "plan_key",
    "read_frame",
    "unpack_body",
    "xla_fallback_plan",
]
