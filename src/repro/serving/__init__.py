"""Serving subsystem — the request loop above ``InferenceEngine``.

``Server`` accepts single-image requests for many networks out of one
process; ``MicroBatcher`` coalesces concurrent requests within a deadline
window into one padded-batch dispatch (batch-1 traffic keeps the paper's
single-image fast path); ``EngineCache`` LRU-caches built engines keyed by
(network, input_size, device, dtype) and reuses tuned plans across
variants; ``StreamSession`` (``Server.open_stream``) serves fixed-rate
frame streams over per-stream engine leases with double-buffered frames,
a skip-to-latest drop policy, and per-frame deadline accounting. See
docs/serving.md for the request and session lifecycles.
"""
from repro.serving.batcher import MicroBatcher, bucket  # noqa: F401
from repro.serving.engine_cache import (  # noqa: F401
    EngineCache,
    EngineLease,
    engine_key,
    plan_key,
)
from repro.serving.request import Request  # noqa: F401
from repro.serving.server import Server  # noqa: F401
from repro.serving.streaming import (  # noqa: F401
    Frame,
    FrameDropped,
    StreamScheduler,
    StreamSession,
)
