"""Serving subsystem — the request loop above ``InferenceEngine``.

``Server`` accepts single-image requests for many networks out of one
process; ``MicroBatcher`` coalesces concurrent requests within a deadline
window into one padded-batch dispatch (batch-1 traffic keeps the paper's
single-image fast path); ``EngineCache`` LRU-caches built engines keyed by
(network, input_size, device, dtype) and reuses tuned plans across
variants; ``StreamSession`` (``Server.open_stream``) serves fixed-rate
frame streams over per-stream engine leases with double-buffered frames,
a skip-to-latest drop policy, and per-frame deadline accounting.

The resilience layer makes the loop overload-safe: bounded admission
(``Overloaded``), deadline shedding at dequeue (``DeadlineExceeded``),
``RetryPolicy`` backoff for transient dispatch failures, a per-engine
``CircuitBreaker`` that degrades persistent failures to the xla-only
fallback plan, and a deterministic ``FaultInjector`` harness threaded
through batchers, the engine cache, and stream sessions. See
docs/serving.md for the request and session lifecycles and the
"Overload & failure semantics" section.
"""
from repro.serving.batcher import MicroBatcher, bucket  # noqa: F401
from repro.serving.engine_cache import (  # noqa: F401
    EngineCache,
    EngineLease,
    engine_key,
    plan_key,
    xla_fallback_plan,
)
from repro.serving.faults import Fault, FaultInjector  # noqa: F401
from repro.serving.request import Request  # noqa: F401
from repro.serving.resilience import (  # noqa: F401
    CircuitBreaker,
    CircuitOpen,
    DeadlineExceeded,
    Overloaded,
    Rejected,
    RetryPolicy,
    TransientFailure,
)
from repro.serving.server import Server  # noqa: F401
from repro.serving.streaming import (  # noqa: F401
    Frame,
    FrameDropped,
    StreamScheduler,
    StreamSession,
)
