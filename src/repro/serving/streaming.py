"""Streaming inference: fixed-rate frame sessions over leased engines.

The paper's scenario is latency-bound single-image inference; the
canonical mobile workload for it is not one-shot classification but a
camera loop — fixed-rate frames with a strict per-frame deadline
(openpilot's driver-monitoring model is the ROADMAP's exemplar). A
``StreamSession`` is one such loop:

  * it owns a **per-stream engine lease** from the ``EngineCache``
    (``EngineCache.lease``): the engine is pinned against LRU eviction for
    the session's lifetime, so a burst of classify traffic for other
    networks can never evict the engine out from under a live stream;
  * frames flow through a **double-buffered input slot**: the host→device
    transfer (``engine.device_put_frame``) starts at frame arrival, on the
    submitting thread, so frame ``t+1``'s transfer overlaps frame ``t``'s
    compute; the jitted streaming forward **donates** the frame buffer, so
    steady-state streaming allocates no fresh device memory per frame;
  * when compute falls behind the frame rate, the **skip-to-latest** drop
    policy discards every queued frame except the newest — the session
    always works on the freshest camera frame instead of building a
    stale-frame backlog;
  * every frame is stamped (arrival / dispatch / done) against the
    session's clock and judged against its **deadline** (default: one
    frame period after arrival), so the session reports a per-stream
    deadline-miss rate, not just throughput.

Two pacing modes share all of that machinery:

  * **threaded** (default, ``sim_compute_s=None``): a daemon thread owns
    dispatch, stamps are wall-clock, and ``submit_frame`` may be called
    from any producer thread at any real rate. This is the deployment
    shape.
  * **simulated clock** (``sim_compute_s=<seconds>``): ``submit_frame``
    processes synchronously and time is pure event arithmetic — frame
    ``k`` of a ``fps``-rate stream arrives at exactly ``k/fps`` and each
    dispatch occupies the device for exactly ``sim_compute_s``. The real
    kernels still run (outputs are bitwise-equal to ``engine.run``), but
    deadline accounting is deterministic: CI can gate on the miss rate.

``StreamScheduler`` drives K simulated sessions in global arrival order —
the multi-stream merge that lets a 4×30 fps scenario share one engine
cache with on-demand ``Server.submit`` classify traffic, deterministically.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax

_STOP = object()


class FrameDropped(RuntimeError):
    """Resolution of a frame skipped by the skip-to-latest drop policy."""


class Clock:
    """Wall clock — the threaded (deployment) time source."""

    def now(self) -> float:
        return time.perf_counter()


@dataclass
class Frame:
    """One frame in flight: stamps are seconds on the session's clock.

    ``arrival`` is when the frame entered the session (and its host→device
    transfer started); ``dispatch`` when compute began; ``done`` when the
    logits were ready. ``deadline`` is absolute (``arrival + deadline_s``);
    ``missed`` is ``done > deadline`` — a dropped frame always counts as
    missed (it never produced output at all).
    """

    seq: int
    arrival: float
    deadline: float
    dispatch: float | None = None
    done: float | None = None
    dropped: bool = False
    missed: bool | None = None
    future: Future = field(default_factory=Future)

    @property
    def latency(self) -> float | None:
        """Seconds from arrival to logits; None if dropped / in flight."""
        return None if self.done is None else self.done - self.arrival


class StreamSession:
    """One fixed-rate frame stream over one leased engine.

    ``lease`` is an ``EngineLease`` (see ``EngineCache.lease``); the
    session owns it and releases it on ``close``. ``fps`` sets the nominal
    frame period; ``deadline_ms`` the per-frame deadline (default: one
    frame period). ``sim_compute_s`` switches to the simulated clock
    (synchronous, deterministic — see module docstring); ``phase_s``
    offsets the simulated stream's first arrival so K streams don't all
    tick at the same instant.
    """

    def __init__(self, lease, *, fps: float = 30.0,
                 deadline_ms: float | None = None, clock: Clock | None = None,
                 sim_compute_s: float | None = None, phase_s: float = 0.0,
                 name: str = "stream", faults=None):
        assert fps > 0
        self.lease = lease
        self.engine = lease.engine
        self.name = name
        # FaultInjector (site "frame"): scripted per-frame errors settle
        # the frame and keep the stream alive; scripted latency spikes
        # sleep in threaded mode and add to the compute charge under the
        # simulated clock (deterministic deadline misses).
        self._faults = faults
        self.period_s = 1.0 / fps
        self.deadline_s = (self.period_s if deadline_ms is None
                           else deadline_ms / 1e3)
        self.clock = clock if clock is not None else Clock()
        self.sim_compute_s = sim_compute_s
        self.frames: list[Frame] = []  # settled (completed or dropped)
        self._lock = threading.Lock()
        self._seq = 0
        self._closed = False
        if sim_compute_s is None:  # threaded: a daemon thread owns dispatch
            self._queue: queue.Queue = queue.Queue()
            self._thread = threading.Thread(
                target=self._loop, daemon=True,
                name=f"stream-{name}-{id(self):x}")
            self._thread.start()
        else:  # simulated clock: pure event-time arithmetic
            assert sim_compute_s > 0
            self._free_at = 0.0           # device-busy horizon
            self._pending = None          # (Frame, device buffer) slot
            self._next_t = float(phase_s)

    # ------------------------------------------------------------------
    # producer side

    @property
    def next_arrival(self) -> float:
        """Simulated mode: the arrival time of the next auto-paced frame
        (``phase_s + k * period``). The scheduler merges streams on it."""
        assert self.sim_compute_s is not None
        return self._next_t

    def submit_frame(self, image) -> Frame:
        """Feed one (H, W, C) frame; returns its ``Frame`` record.

        The host→device transfer starts here, on the calling thread —
        in threaded mode that is the double-buffer overlap: frame ``t+1``
        transfers while the dispatch thread computes frame ``t``. The
        frame's future resolves to the (classes,) logits, or raises
        ``FrameDropped`` if skip-to-latest discarded it.
        """
        if self._closed:
            raise RuntimeError("stream session is closed")
        if self.sim_compute_s is None:
            arrival = self.clock.now()
        else:
            arrival = self._next_t
            self._next_t += self.period_s
        buf = self.engine.device_put_frame(image)  # async transfer starts
        frame = Frame(seq=self._seq, arrival=arrival,
                      deadline=arrival + self.deadline_s)
        self._seq += 1
        if self.sim_compute_s is None:
            self._queue.put((frame, buf))
        else:
            self._submit_sim(frame, buf)
        return frame

    def flush(self) -> None:
        """Settle every submitted frame (simulated mode: dispatch the
        pending slot; threaded mode: wait for the queue to drain)."""
        if self.sim_compute_s is not None:
            self._drain_sim(float("inf"))
        else:
            self._queue.join()

    def close(self) -> None:
        """Flush, stop the dispatch thread, release the engine lease."""
        if self._closed:
            return
        self._closed = True
        if self.sim_compute_s is None:
            self._queue.put((_STOP, None))
            self._thread.join(30.0)
            # a submit racing close() can enqueue behind the stop
            # sentinel; settle those frames instead of leaving futures
            # unresolved (same contract as MicroBatcher.close)
            while True:
                try:
                    frame, _ = self._queue.get_nowait()
                except queue.Empty:
                    break
                if frame is not _STOP:
                    self._drop(frame)
                self._queue.task_done()
        else:
            self._drain_sim(float("inf"))
        self.lease.release()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    # simulated-clock mode: synchronous, deterministic event arithmetic

    def _submit_sim(self, frame: Frame, buf) -> None:
        self._drain_sim(frame.arrival)
        if self._free_at <= frame.arrival:  # device idle: dispatch now
            self._run_frame(frame, buf, dispatch=frame.arrival)
        else:  # device busy: the new frame takes the single pending slot
            if self._pending is not None:  # skip-to-latest: drop the old
                self._drop(self._pending[0])
            self._pending = (frame, buf)

    def _drain_sim(self, now: float) -> None:
        """Dispatch the pending frame if the device frees by ``now``
        (``inf`` forces it out — flush/close)."""
        if self._pending is not None and self._free_at <= now:
            frame, buf = self._pending
            self._pending = None
            self._run_frame(frame, buf, dispatch=self._free_at)

    # ------------------------------------------------------------------
    # threaded mode: a dispatch loop with skip-to-latest on its queue

    def _loop(self) -> None:
        stopping = False
        while not stopping:
            frame, buf = self._queue.get()
            if frame is _STOP:
                self._queue.task_done()
                break
            # skip-to-latest: everything queued behind the in-flight
            # compute is stale except the newest frame — drop the rest
            while True:
                try:
                    nxt, nbuf = self._queue.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stopping = True
                    self._queue.task_done()
                    break
                self._drop(frame)
                self._queue.task_done()
                frame, buf = nxt, nbuf
            self._run_frame(frame, buf, dispatch=self.clock.now())
            self._queue.task_done()

    # ------------------------------------------------------------------
    # shared: dispatch + settle

    def _run_frame(self, frame: Frame, buf, *, dispatch: float) -> None:
        frame.dispatch = dispatch
        delay = 0.0
        try:
            if self._faults is not None:
                delay = self._faults.check("frame")
                if delay and self.sim_compute_s is None:
                    time.sleep(delay)  # sim mode charges it arithmetically
            logits = jax.block_until_ready(self.engine.run_stream(buf))
        except Exception as e:  # settle the frame, keep the stream alive
            frame.done = (dispatch + self.sim_compute_s
                          if self.sim_compute_s is not None
                          else self.clock.now())
            frame.missed = True
            with self._lock:
                self.frames.append(frame)
            frame.future.set_exception(e)
            return
        if self.sim_compute_s is not None:
            # injected latency joins the deterministic compute charge, so
            # a scripted spike produces the exact same miss accounting on
            # every run — chaos tests gate on it
            frame.done = dispatch + self.sim_compute_s + delay
            self._free_at = frame.done
        else:
            frame.done = self.clock.now()
        frame.missed = frame.done > frame.deadline
        with self._lock:
            self.frames.append(frame)
        frame.future.set_result(logits)

    def _drop(self, frame: Frame) -> None:
        frame.dropped = True
        frame.missed = True  # a dropped frame never met its deadline
        with self._lock:
            self.frames.append(frame)
        frame.future.set_exception(FrameDropped(
            f"frame {frame.seq} skipped: compute fell behind the "
            f"{1.0 / self.period_s:g} fps frame rate"))

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Per-stream deadline accounting over the settled frames."""
        with self._lock:
            frames = list(self.frames)
        completed = [f for f in frames if not f.dropped and f.done is not None]
        dropped = [f for f in frames if f.dropped]
        total = len(completed) + len(dropped)
        misses = sum(1 for f in frames if f.missed)
        lats = sorted(f.latency for f in completed)

        def pct(q):
            if not lats:
                return None
            return lats[min(len(lats) - 1, round(q / 100 * (len(lats) - 1)))]

        span = (max(f.done for f in completed)
                - min(f.arrival for f in frames)) if completed else None
        return {
            "name": self.name,
            "dtype": self.engine.cfg.dtype,
            "fps_target": 1.0 / self.period_s,
            "deadline_ms": self.deadline_s * 1e3,
            "frames": total,
            "completed": len(completed),
            "dropped": len(dropped),
            "deadline_misses": misses,
            "deadline_miss_rate": misses / total if total else None,
            "fps_achieved": len(completed) / span if span else None,
            "latency_p50_s": pct(50),
            "latency_p95_s": pct(95),
            "latency_max_s": lats[-1] if lats else None,
        }


class StreamScheduler:
    """Drive K simulated-clock sessions in global arrival order.

    The next frame to arrive *across all streams* is submitted next, so K
    fixed-rate streams interleave exactly as their timestamps dictate —
    the deterministic multi-stream merge the bench gate runs. (Threaded
    sessions don't need a scheduler: each owns a dispatch thread, which is
    what keeps one stream's compute from head-of-line-blocking another's.)
    """

    def __init__(self, sessions):
        self.sessions = list(sessions)
        assert self.sessions
        assert all(s.sim_compute_s is not None for s in self.sessions), \
            "StreamScheduler drives simulated-clock sessions only"

    def run(self, n_frames: int, image_fn) -> list[list[Frame]]:
        """Submit ``n_frames`` per stream, ``image_fn(stream_idx, seq)``
        supplying each frame; flushes every session and returns the Frame
        records grouped per stream."""
        heap = [(s.next_arrival, i, 0) for i, s in enumerate(self.sessions)]
        heapq.heapify(heap)
        frames: list[list[Frame]] = [[] for _ in self.sessions]
        while heap:
            _, i, k = heapq.heappop(heap)
            s = self.sessions[i]
            frames[i].append(s.submit_frame(image_fn(i, k)))
            if k + 1 < n_frames:
                heapq.heappush(heap, (s.next_arrival, i, k + 1))
        for s in self.sessions:
            s.flush()
        return frames
