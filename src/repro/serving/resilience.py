"""Resilience primitives for the serving front door.

Production serving fails in layers, and each layer wants a different
response:

  * **shed** — the request is not worth starting: the queue is full
    (``Overloaded``), the deadline already passed or the client gave up
    (``DeadlineExceeded``), or the engine is known-sick (``CircuitOpen``).
    Shedding is *cheap by construction*: it happens at admission or at
    dequeue, never after compute was spent.
  * **retry** — the dispatch failed but the failure is transient
    (``TransientFailure``, the same type the training runtime's
    checkpoint/restart loop keys on — one vocabulary for "try again"
    across the repo). Retries back off exponentially with a cap, so a
    blip costs milliseconds and a real outage doesn't hammer the device.
  * **degrade** — the failure is persistent (``CircuitBreaker`` trips
    after N consecutive failures). The owner swaps the tuned engine for
    an xla-only fallback and keeps serving at reduced speed instead of
    going dark; the breaker's open state sheds fast in the meantime.

Every rejection subclasses ``Rejected`` (itself a ``RuntimeError``), so
callers can distinguish "the server said no" from "the computation
broke" with one ``except`` clause.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

# The shared transient-error vocabulary: serving retries exactly what the
# training runtime's restart loop replays.
from repro.runtime.fault_tolerance import TransientFailure  # noqa: F401


class Rejected(RuntimeError):
    """Base of every typed serving rejection (the server said no before
    spending compute — distinct from a dispatch *error*)."""


class Overloaded(Rejected):
    """Admission control: the bounded queue is full (or the target is
    closed) — the request was shed at the front door."""


class DeadlineExceeded(Rejected):
    """The request expired (or its client cancelled) before dispatch —
    shed at dequeue, before any compute was spent on it."""


class CircuitOpen(Rejected):
    """The engine's circuit breaker is open: recent dispatches failed
    persistently, so requests shed fast instead of queueing behind a
    sick engine."""


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff for transient dispatch failures.

    Attempt ``k`` (0-based) sleeps ``min(backoff_s * 2**k, backoff_cap_s)``
    before retrying; ``max_retries`` bounds the retries *after* the first
    attempt (``max_retries=2`` means at most 3 attempts total).
    """

    max_retries: int = 2
    backoff_s: float = 0.001
    backoff_cap_s: float = 0.050

    def delay(self, attempt: int) -> float:
        return min(self.backoff_s * (2 ** attempt), self.backoff_cap_s)


class CircuitBreaker:
    """Per-engine breaker: trip open after N *consecutive* failures.

    States:

      * **closed** — normal operation; failures increment a consecutive
        counter, any success resets it.
      * **open** — ``threshold`` consecutive failures were recorded;
        ``allow()`` returns False (callers shed with ``CircuitOpen``)
        until ``reset_s`` elapses.
      * **half_open** — the cooldown elapsed; ``allow()`` admits one
        probe. Success closes the breaker, failure re-opens it for
        another full cooldown.

    Thread-safe; ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, threshold: int = 5, reset_s: float = 30.0,
                 clock=time.perf_counter):
        assert threshold >= 1
        self.threshold = threshold
        self.reset_s = reset_s
        self._clock = clock
        self._lock = threading.Lock()
        self._failures = 0     # consecutive
        self._opened_at: float | None = None
        self._probing = False  # half-open: one probe in flight
        self.trips = 0         # lifetime closed->open transitions

    @property
    def state(self) -> str:
        with self._lock:
            return self._state_locked()

    def _state_locked(self) -> str:
        if self._opened_at is None:
            return "closed"
        if self._probing or self._clock() - self._opened_at >= self.reset_s:
            return "half_open"
        return "open"

    def allow(self) -> bool:
        """May a dispatch proceed? False while open; in half-open, True
        exactly once per cooldown (the probe)."""
        with self._lock:
            state = self._state_locked()
            if state == "closed":
                return True
            if state == "half_open" and not self._probing:
                self._probing = True
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> bool:
        """Count one failure; returns True iff this failure trips (or
        re-trips) the breaker open."""
        with self._lock:
            if self._probing:  # the half-open probe failed: re-open
                self._probing = False
                self._opened_at = self._clock()
                return True
            self._failures += 1
            if self._opened_at is None and self._failures >= self.threshold:
                self._opened_at = self._clock()
                self.trips += 1
                return True
            return False

    def reset(self) -> None:
        """Force-close (the owner swapped in a healthy engine)."""
        self.record_success()

    def stats(self) -> dict:
        with self._lock:
            return {"state": self._state_locked(),
                    "consecutive_failures": self._failures,
                    "threshold": self.threshold,
                    "trips": self.trips}
