"""The serving front door: single-image requests in, logits tickets out.

One ``Server`` owns one ``EngineCache`` (shared across every network it
serves), one ``MicroBatcher`` per active network, and one
``DeviceScheduler`` that all batchers dispatch through — N networks'
forming batches interleave onto the accelerator oldest-deadline-first, so
a cold or slow network cannot head-of-line block a fast one. ``submit``
routes a request to its network's batcher — building the engine through
the cache on first sight — and returns immediately with a ``Ticket``.
``open_stream`` opens a fixed-rate ``StreamSession`` over the same cache:
the session holds an engine lease (pinned against eviction) and its
dispatch runs on its own thread. This is the seam every future scaling
layer (sharding, multi-backend, remote endpoints) plugs into: everything
above it speaks (network, image) -> logits, everything below it is the
tuned-engine world. The wire tier (``serving/protocol.py`` +
``serving/client.py``) sits on top of exactly this surface.

Configuration is two frozen options objects: ``ServingOptions`` for the
server-wide knobs (batching window, admission bound, shed deadline,
retry/breaker policy, fault injection) and ``RequestOptions`` for
per-call ones (dtype variant, deadline override, scheduler priority).
The pre-PR-10 kwarg spellings (``Server(max_queue=..., deadline_ms=...,
...)``, ``submit(..., dtype=...)``) still work through a deprecation
shim that folds them into the options objects and warns once per call
site.

The front door is overload-safe (docs/serving.md "Overload & failure
semantics"): ``max_queue`` bounds every batcher's queue and rejects
beyond it with ``Overloaded``; ``deadline_ms`` sheds expired requests at
dequeue (``DeadlineExceeded``) instead of computing them late; transient
dispatch failures retry with capped backoff; persistent failures trip a
per-engine circuit breaker, which swaps the engine for an xla-only
degraded build through ``EngineCache.degrade`` and keeps serving.
``faults=`` threads one ``FaultInjector`` through the batchers, the
cache, and every stream session — the deterministic chaos-test hook.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from dataclasses import dataclass

from repro.serving.batcher import MicroBatcher
from repro.serving.engine_cache import EngineCache, engine_key
from repro.serving.request import RequestOptions, Ticket
from repro.serving.resilience import CircuitBreaker, Overloaded, RetryPolicy
from repro.serving.scheduler import DeviceScheduler
from repro.serving.streaming import StreamSession


@dataclass(frozen=True)
class ServingOptions:
    """Server-wide serving knobs (frozen — share one object freely).

    ``max_batch`` / ``window_ms`` configure every batcher's forming
    batch; ``deadline_ms`` is the default per-request shed deadline (a
    ``RequestOptions.deadline_ms`` overrides it per call); ``max_queue``
    bounds admission; ``retry`` / ``breaker_threshold`` /
    ``breaker_reset_s`` configure the resilience layer; ``faults`` is
    the chaos-test injection harness. Defaults keep the seed behavior
    (unbounded queue, no deadline, breaker wide at 5 consecutive
    failures).
    """

    max_batch: int = 8
    window_ms: float = 2.0
    deadline_ms: float | None = None
    max_queue: int | None = None
    retry: RetryPolicy | None = None
    breaker_threshold: int = 5
    breaker_reset_s: float = 30.0
    faults: object = None


# the ServingOptions fields that used to be Server(...) kwargs — the
# deprecation shim accepts exactly these and nothing else
_LEGACY_KEYS = tuple(f.name for f in dataclasses.fields(ServingOptions))


class Server:
    """Micro-batched multi-network serving out of one process.

    ``tiny=True`` maps network names through ``tiny_variant`` (the
    CPU/CI path). ``capacity`` bounds the engine cache; everything else
    lives on ``options`` (a ``ServingOptions``). The old flat kwargs
    (``max_batch=``, ``max_queue=``, ...) still work via a deprecation
    shim and build a bit-identical server.
    """

    def __init__(self, *, options: ServingOptions | None = None,
                 cache: EngineCache | None = None, capacity: int = 4,
                 tune_mode: str = "cost_model", tiny: bool = False,
                 **legacy):
        if legacy:
            unknown = sorted(set(legacy) - set(_LEGACY_KEYS))
            if unknown:
                raise TypeError(
                    f"Server() got unexpected keyword argument(s): "
                    f"{', '.join(unknown)}")
            if options is not None:
                raise ValueError(
                    "pass ServingOptions OR legacy kwargs, not both: "
                    f"options={options!r} conflicts with "
                    f"{sorted(legacy)}")
            warnings.warn(
                f"Server({', '.join(sorted(legacy))}=...) kwargs are "
                f"deprecated; pass options=ServingOptions(...) instead "
                f"(see docs/serving.md, 'Front door')",
                DeprecationWarning, stacklevel=2)
            options = dataclasses.replace(ServingOptions(), **legacy)
        self.options = options if options is not None else ServingOptions()
        self.faults = self.options.faults
        self.engines = cache if cache is not None else EngineCache(
            capacity=capacity, tune_mode=tune_mode, faults=self.faults)
        self.tiny = tiny
        # one device, one scheduler: every batcher dispatch funnels
        # through it under the oldest-deadline-first fairness policy
        self.scheduler = DeviceScheduler()
        self._batchers: dict[tuple, MicroBatcher] = {}
        self._streams: list[StreamSession] = []
        self._lock = threading.Lock()
        self._closed = False

    # -- legacy read access (old call sites read these off the server) --

    @property
    def max_batch(self):
        return self.options.max_batch

    @property
    def window_ms(self):
        return self.options.window_ms

    @property
    def deadline_ms(self):
        return self.options.deadline_ms

    @property
    def max_queue(self):
        return self.options.max_queue

    # ------------------------------------------------------------------

    def _resolve_cfg(self, network, dtype=None):
        if isinstance(network, str):
            from repro.configs import get, tiny_variant

            cfg = get(network)
            if self.tiny:
                cfg = tiny_variant(cfg)
        else:
            cfg = network
        if dtype is not None:
            from repro.core.dtypes import with_precision

            cfg = with_precision(cfg, dtype)
        return cfg

    def _batcher(self, cfg) -> MicroBatcher:
        key = engine_key(cfg)
        with self._lock:
            b = self._batchers.get(key)
        if b is not None:
            return b
        # Build (or fetch) the engine OUTSIDE the server lock: the cache
        # serializes builds per key, so a cold network never stalls
        # submits for already-warm ones. The batcher holds its own engine
        # reference, so cache eviction frees the slot without yanking an
        # engine mid-flight.
        engine = self.engines.get(cfg)
        opts = self.options
        with self._lock:
            b = self._batchers.get(key)
            if b is None:  # we won (or were alone): register our batcher
                retry = opts.retry if opts.retry is not None \
                    else RetryPolicy()
                b = MicroBatcher(
                    engine, max_batch=opts.max_batch,
                    window_ms=opts.window_ms, deadline_ms=opts.deadline_ms,
                    max_queue=opts.max_queue, retry=retry,
                    breaker=CircuitBreaker(threshold=opts.breaker_threshold,
                                           reset_s=opts.breaker_reset_s),
                    # the degraded-mode hook: a tripped breaker rebuilds
                    # this key's cache entry on the xla fallback plan
                    degrade=lambda cfg=cfg: self.engines.degrade(cfg),
                    faults=self.faults,
                    scheduler=self.scheduler,
                    name=self._stats_key(key))
                self._batchers[key] = b
            return b

    # ------------------------------------------------------------------

    @staticmethod
    def _request_options(options, dtype):
        """Fold a deprecated per-call ``dtype=`` into the options object
        (warning once); conflicting values are a ValueError."""
        if dtype is not None:
            warnings.warn(
                "the per-call dtype= kwarg is deprecated; pass "
                "options=RequestOptions(dtype=...) instead "
                "(see docs/serving.md, 'Front door')",
                DeprecationWarning, stacklevel=3)
        opts = options if options is not None else RequestOptions()
        return opts.merged_dtype(dtype)

    def submit(self, network, image, *, options: RequestOptions | None = None,
               dtype=None) -> Ticket:
        """Non-blocking: route one (H, W, C) image to ``network``'s
        batcher; returns a ``Ticket`` resolving to (classes,) logits.

        ``options.dtype`` is the precision knob (``"bfloat16"`` serves
        from the network's bf16 variant — own engine-cache entry, own
        dtype-keyed plan); ``options.deadline_ms`` overrides the server's
        shed deadline for this request; ``options.priority`` biases the
        device scheduler. ``dtype=`` is the deprecated spelling of
        ``options.dtype``.

        Raises ``Overloaded`` (a typed rejection) if the server is closed
        or the target batcher's bounded queue is full.
        """
        return Ticket(self._submit_request(network, image,
                                           options=options, dtype=dtype))

    def _submit_request(self, network, image, *, options=None, dtype=None):
        opts = self._request_options(options, dtype)
        # the closed check happens under the lock, so a submit racing
        # close() either lands before the batchers drain (and resolves)
        # or is rejected here with the same typed error as shedding
        with self._lock:
            if self._closed:
                raise Overloaded("server is closed")
        cfg = self._resolve_cfg(network, opts.dtype)
        return self._batcher(cfg).submit_request(
            image, deadline_ms=opts.deadline_ms, priority=opts.priority)

    def run(self, network, image, timeout: float | None = 120.0, *,
            options: RequestOptions | None = None, dtype=None):
        """Blocking convenience: ``submit(...).result(timeout)``.

        On timeout the request is **cancelled** (via ``Ticket.result``):
        if it is still queued, the batcher sheds it at dequeue
        (``DeadlineExceeded``) instead of burning a dispatch on a result
        nobody is waiting for.
        """
        return self.submit(network, image, options=options,
                           dtype=dtype).result(timeout)

    def warm(self, network, *, options: RequestOptions | None = None,
             dtype=None) -> None:
        """Build ``network``'s engine + batcher ahead of traffic (the
        tune/jit cost moves out of the first request's latency); with a
        dtype set, warms that precision variant."""
        opts = self._request_options(options, dtype)
        self._batcher(self._resolve_cfg(network, opts.dtype))

    def open_stream(self, network, *, fps: float = 30.0,
                    deadline_ms: float | None = None,
                    sim_compute_s: float | None = None,
                    phase_s: float = 0.0,
                    name: str | None = None,
                    dtype=None) -> StreamSession:
        """Open a fixed-rate frame stream on ``network``.

        The session leases the engine from the shared cache — pinned
        against LRU eviction until the session closes — and dispatches on
        its own thread (or synchronously, under the simulated clock when
        ``sim_compute_s`` is set), so streams never head-of-line-block
        each other or the on-demand batchers. Closing the server closes
        every still-open session. ``dtype`` opens the stream on the
        network's precision variant (same knob as ``submit``) — a bf16
        stream leases the bf16 engine, pinned independently of the fp32
        one.
        """
        with self._lock:
            if self._closed:
                raise Overloaded("server is closed")
        cfg = self._resolve_cfg(network, dtype)
        lease = self.engines.lease(cfg)
        with self._lock:
            if name is None:
                name = f"{cfg.name}#{len(self._streams)}"
            session = StreamSession(lease, fps=fps, deadline_ms=deadline_ms,
                                    sim_compute_s=sim_compute_s,
                                    phase_s=phase_s, name=name,
                                    faults=self.faults)
            self._streams.append(session)
            return session

    def close(self) -> None:
        """Flush every batcher and stream (pending requests and frames
        still resolve; stream leases are released), then stop the device
        scheduler. Idempotent: the closed flag flips under the lock, so a
        racing submit either beats the flip (and drains normally) or gets
        the typed rejection."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            batchers = list(self._batchers.values())
            streams = list(self._streams)
        for s in streams:
            s.close()
        for b in batchers:
            b.close()
        # batchers first: their drains still need the device thread
        self.scheduler.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------

    @staticmethod
    def _stats_key(key: tuple) -> str:
        """Human-readable per-network stats key. Includes the compute
        dtype (since PR 7 dtype joins ``engine_key``, fp32 and bf16
        variants of one network are distinct batchers — keying stats by
        (network, input_size) alone made them overwrite each other), and
        the param dtype when it differs from the compute dtype."""
        name, img, _device, dtype, param_dtype = key
        parts = [str(name), str(img), str(dtype)]
        if param_dtype != dtype:
            parts.append(f"params={param_dtype}")
        return "/".join(parts)

    def stats(self) -> dict:
        """Cache counters (including degraded-mode rebuilds), per-network
        batcher aggregates (queue depth, mid-flight joins, dispatch
        causes, shed/retry/breaker telemetry), device-scheduler queue
        stats, per-stream deadline stats."""
        with self._lock:
            per_net = {self._stats_key(k): b.stats()
                       for k, b in self._batchers.items()}
            streams = {s.name: s.stats() for s in self._streams}
        cache = self.engines.stats()
        return {"cache": cache, "networks": per_net, "streams": streams,
                "scheduler": self.scheduler.stats(),
                "degraded": cache["degraded"]}
