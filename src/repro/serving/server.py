"""The serving front door: single-image requests in, logits futures out.

One ``Server`` owns one ``EngineCache`` (shared across every network it
serves) and one ``MicroBatcher`` per active network. ``submit`` routes a
request to its network's batcher — building the engine through the cache
on first sight — and returns immediately with a Future. ``open_stream``
opens a fixed-rate ``StreamSession`` over the same cache: the session
holds an engine lease (pinned against eviction) and its dispatch runs on
its own thread, so K live streams and on-demand classify traffic share
one cache without head-of-line blocking. This is the seam every future
scaling layer (sharding, multi-backend, continuous batching) plugs into:
everything above it speaks (network, image) -> logits, everything below
it is the tuned-engine world.

The front door is overload-safe (docs/serving.md "Overload & failure
semantics"): ``max_queue`` bounds every batcher's queue and rejects
beyond it with ``Overloaded``; ``deadline_ms`` sheds expired requests at
dequeue (``DeadlineExceeded``) instead of computing them late; transient
dispatch failures retry with capped backoff; persistent failures trip a
per-engine circuit breaker, which swaps the engine for an xla-only
degraded build through ``EngineCache.degrade`` and keeps serving.
``faults=`` threads one ``FaultInjector`` through the batchers, the
cache, and every stream session — the deterministic chaos-test hook.
"""
from __future__ import annotations

import threading
from concurrent.futures import TimeoutError as FutureTimeoutError

from repro.serving.batcher import MicroBatcher
from repro.serving.engine_cache import EngineCache, engine_key
from repro.serving.resilience import CircuitBreaker, Overloaded, RetryPolicy
from repro.serving.streaming import StreamSession


class Server:
    """Micro-batched multi-network serving out of one process.

    ``networks`` are named configs (``get(name)``) or ArchConfig objects;
    ``tiny=True`` maps names through ``tiny_variant`` (the CPU/CI path).
    ``capacity`` bounds the engine cache; ``max_batch`` / ``window_ms``
    configure every batcher. ``max_queue`` (admission bound),
    ``deadline_ms`` (shed deadline + SLO telemetry), ``retry`` (transient
    backoff policy), ``breaker_threshold`` / ``breaker_reset_s`` (circuit
    breaker), and ``faults`` (injection harness) configure the resilience
    layer; defaults keep the seed behavior (unbounded queue, no deadline,
    breaker wide at 5 consecutive failures).
    """

    def __init__(self, *, cache: EngineCache | None = None, capacity: int = 4,
                 tune_mode: str = "cost_model", max_batch: int = 8,
                 window_ms: float = 2.0, deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 retry: RetryPolicy | None = None,
                 breaker_threshold: int = 5, breaker_reset_s: float = 30.0,
                 faults=None, tiny: bool = False):
        self.faults = faults
        self.engines = cache if cache is not None else EngineCache(
            capacity=capacity, tune_mode=tune_mode, faults=faults)
        self.max_batch = max_batch
        self.window_ms = window_ms
        self.deadline_ms = deadline_ms  # per-request SLO + shed deadline
        self.max_queue = max_queue
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker_threshold = breaker_threshold
        self.breaker_reset_s = breaker_reset_s
        self.tiny = tiny
        self._batchers: dict[tuple, MicroBatcher] = {}
        self._streams: list[StreamSession] = []
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------------

    def _resolve_cfg(self, network, dtype=None):
        if isinstance(network, str):
            from repro.configs import get, tiny_variant

            cfg = get(network)
            if self.tiny:
                cfg = tiny_variant(cfg)
        else:
            cfg = network
        if dtype is not None:
            from repro.core.dtypes import with_precision

            cfg = with_precision(cfg, dtype)
        return cfg

    def _batcher(self, cfg) -> MicroBatcher:
        key = engine_key(cfg)
        with self._lock:
            b = self._batchers.get(key)
        if b is not None:
            return b
        # Build (or fetch) the engine OUTSIDE the server lock: the cache
        # serializes builds per key, so a cold network never stalls
        # submits for already-warm ones. The batcher holds its own engine
        # reference, so cache eviction frees the slot without yanking an
        # engine mid-flight.
        engine = self.engines.get(cfg)
        with self._lock:
            b = self._batchers.get(key)
            if b is None:  # we won (or were alone): register our batcher
                b = MicroBatcher(
                    engine, max_batch=self.max_batch,
                    window_ms=self.window_ms, deadline_ms=self.deadline_ms,
                    max_queue=self.max_queue, retry=self.retry,
                    breaker=CircuitBreaker(threshold=self.breaker_threshold,
                                           reset_s=self.breaker_reset_s),
                    # the degraded-mode hook: a tripped breaker rebuilds
                    # this key's cache entry on the xla fallback plan
                    degrade=lambda cfg=cfg: self.engines.degrade(cfg),
                    faults=self.faults)
                self._batchers[key] = b
            return b

    # ------------------------------------------------------------------

    def submit(self, network, image, *, dtype=None):
        """Non-blocking: route one (H, W, C) image to ``network``'s
        batcher; returns a Future resolving to (classes,) logits.

        ``dtype`` is the precision knob: ``dtype="bfloat16"`` serves the
        request from the network's bf16 variant (own engine-cache entry,
        own dtype-keyed tuning plan, images cast in the forward); ``None``
        serves at the config's native precision.

        Raises ``Overloaded`` (a typed rejection) if the server is closed
        or the target batcher's bounded queue is full.
        """
        return self._submit_request(network, image, dtype=dtype).future

    def _submit_request(self, network, image, *, dtype=None):
        # the closed check happens under the lock, so a submit racing
        # close() either lands before the batchers drain (and resolves)
        # or is rejected here with the same typed error as shedding
        with self._lock:
            if self._closed:
                raise Overloaded("server is closed")
        cfg = self._resolve_cfg(network, dtype)
        return self._batcher(cfg).submit_request(image)

    def run(self, network, image, timeout: float | None = 120.0, *,
            dtype=None):
        """Blocking convenience: submit + await one request.

        On timeout the request is **cancelled**: if it is still queued,
        the batcher sheds it at dequeue (``DeadlineExceeded``) instead of
        burning a dispatch on a result nobody is waiting for.
        """
        req = self._submit_request(network, image, dtype=dtype)
        try:
            return req.future.result(timeout)
        except FutureTimeoutError:
            req.cancel()
            raise

    def warm(self, network, *, dtype=None) -> None:
        """Build ``network``'s engine + batcher ahead of traffic (the
        tune/jit cost moves out of the first request's latency); with
        ``dtype`` set, warms that precision variant."""
        self._batcher(self._resolve_cfg(network, dtype))

    def open_stream(self, network, *, fps: float = 30.0,
                    deadline_ms: float | None = None,
                    sim_compute_s: float | None = None,
                    phase_s: float = 0.0,
                    name: str | None = None,
                    dtype=None) -> StreamSession:
        """Open a fixed-rate frame stream on ``network``.

        The session leases the engine from the shared cache — pinned
        against LRU eviction until the session closes — and dispatches on
        its own thread (or synchronously, under the simulated clock when
        ``sim_compute_s`` is set), so streams never head-of-line-block
        each other or the on-demand batchers. Closing the server closes
        every still-open session. ``dtype`` opens the stream on the
        network's precision variant (same knob as ``submit``) — a bf16
        stream leases the bf16 engine, pinned independently of the fp32
        one.
        """
        with self._lock:
            if self._closed:
                raise Overloaded("server is closed")
        cfg = self._resolve_cfg(network, dtype)
        lease = self.engines.lease(cfg)
        with self._lock:
            if name is None:
                name = f"{cfg.name}#{len(self._streams)}"
            session = StreamSession(lease, fps=fps, deadline_ms=deadline_ms,
                                    sim_compute_s=sim_compute_s,
                                    phase_s=phase_s, name=name,
                                    faults=self.faults)
            self._streams.append(session)
            return session

    def close(self) -> None:
        """Flush every batcher and stream (pending requests and frames
        still resolve; stream leases are released). Idempotent: the
        closed flag flips under the lock, so a racing submit either beats
        the flip (and drains normally) or gets the typed rejection."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            batchers = list(self._batchers.values())
            streams = list(self._streams)
        for s in streams:
            s.close()
        for b in batchers:
            b.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------

    @staticmethod
    def _stats_key(key: tuple) -> str:
        """Human-readable per-network stats key. Includes the compute
        dtype (since PR 7 dtype joins ``engine_key``, fp32 and bf16
        variants of one network are distinct batchers — keying stats by
        (network, input_size) alone made them overwrite each other), and
        the param dtype when it differs from the compute dtype."""
        name, img, _device, dtype, param_dtype = key
        parts = [str(name), str(img), str(dtype)]
        if param_dtype != dtype:
            parts.append(f"params={param_dtype}")
        return "/".join(parts)

    def stats(self) -> dict:
        """Cache counters (including degraded-mode rebuilds), per-network
        batcher aggregates (queue depth, dispatch causes, shed/retry/
        breaker telemetry), per-stream deadline stats."""
        with self._lock:
            per_net = {self._stats_key(k): b.stats()
                       for k, b in self._batchers.items()}
            streams = {s.name: s.stats() for s in self._streams}
        cache = self.engines.stats()
        return {"cache": cache, "networks": per_net, "streams": streams,
                "degraded": cache["degraded"]}
