"""LRU cache of built ``InferenceEngine``s + tuned-plan reuse.

Many model variants (resnet18/50, mobilenet_v2, tiny variants) share one
serving process. Building an engine is expensive — tune a plan, precompute
Winograd transforms, jit the forward — so the cache keys each built engine
by ``(network, input_size, device, compute_dtype, param_dtype)`` and
evicts least-recently-used beyond ``capacity``.

Plans are cached separately, keyed by ``(network, input_size,
compute_dtype)``: a ``TuningPlan`` is device-agnostic, but NOT
dtype-agnostic — ConvSpec carries the compute dtype, byte-traffic terms
scale with its element width, and the tuned algorithm can flip between
fp32 and bf16 for the same geometry. Engines that differ only in
``param_dtype`` (storage precision of the weights) still share a plan:
the plan was tuned for the compute dtype, which is what the kernels
stream. The seed keyed plans by geometry alone, silently deploying fp32
choices onto reduced-precision engines; ConvSpec's dtype field now makes
the engine's plan validation reject exactly that, so the key must match.

Builds are fault-tolerant: a transient build failure retries with capped
backoff, and a build that fails *persistently while deploying a cached
plan* falls back to the xla-only plan (``xla_fallback_plan``) instead of
failing every request for the key. ``degrade(cfg)`` is the same fallback
on demand — the batcher calls it when an engine's circuit breaker trips —
and ``stats()`` counts both under ``degraded``.

Streaming sessions hold **leases** (``lease``): a leased entry is pinned —
it does not count against ``capacity`` and LRU eviction skips it — so a
burst of classify traffic for other networks can never evict the engine
out from under a live stream. Releasing the lease returns the entry to
normal LRU order as most-recently-used.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import OrderedDict

import jax

from repro.core.engine import InferenceEngine
from repro.serving.resilience import RetryPolicy, TransientFailure

log = logging.getLogger("repro.serving")


def xla_fallback_plan(cfg):
    """The degraded-mode plan for ``cfg``: every conv site on the xla
    escape hatch, no fused blocks — same geometry/dtype enumeration as a
    tuned plan, so engine plan-validation accepts it unchanged."""
    from repro.core import autotune
    from repro.models.registry import cnn_module

    return autotune.xla_fallback_plan(cnn_module(cfg).conv_specs(cfg))


def engine_key(cfg, device: str | None = None) -> tuple:
    """The cache key: (network, input_size, device, dtype, param_dtype).

    ``device`` defaults to the platform of the default JAX device — the
    thing kernel lowering actually varies over. Compute dtype and param
    (storage) dtype key independently: they change the jitted program.
    """
    if device is None:
        device = jax.devices()[0].platform
    return (cfg.name, cfg.extra.get("img"), device, cfg.dtype,
            cfg.param_dtype)


def plan_key(cfg) -> tuple:
    """Plan reuse key: (network, input_size, compute_dtype).

    Plans are tuned per compute dtype — element width moves every byte
    term of the cost model — but are independent of ``param_dtype``
    (weight storage) and device (the plan is an offline artifact).
    """
    return (cfg.name, cfg.extra.get("img"), cfg.dtype)


class EngineLease:
    """A pin on one cache entry, held by a ``StreamSession`` for its
    lifetime: while any lease on the key is live, the engine is exempt
    from LRU eviction (and from the capacity count). ``release`` — or
    exiting the context manager — drops the pin and restores the entry to
    normal LRU order as most-recently-used."""

    def __init__(self, cache: "EngineCache", key: tuple,
                 engine: InferenceEngine):
        self._cache = cache
        self.key = key
        self.engine = engine
        self._released = False

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._cache._release(self.key)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


class EngineCache:
    """Thread-safe LRU of InferenceEngines; hit returns the *identical*
    engine object (same jitted forward, same params, same plan)."""

    def __init__(self, capacity: int = 4, tune_mode: str = "cost_model",
                 retry: RetryPolicy | None = None, faults=None):
        assert capacity >= 1
        self.capacity = capacity
        self.tune_mode = tune_mode
        self.retry = retry if retry is not None else RetryPolicy()
        self._faults = faults  # FaultInjector, or None
        self._engines: OrderedDict[tuple, InferenceEngine] = OrderedDict()
        self._plans: dict[tuple, object] = {}
        self._lock = threading.RLock()
        self._build_locks: dict[tuple, threading.Lock] = {}
        self._pins: dict[tuple, int] = {}  # key -> live lease count
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.leases = 0
        self.degraded = 0  # engines (re)built on the xla fallback plan
        self.build_retries = 0
        self._degraded_keys: set[tuple] = set()

    def __len__(self) -> int:
        return len(self._engines)

    def __contains__(self, cfg) -> bool:
        return engine_key(cfg) in self._engines

    def get(self, cfg, *, params=None, seed: int = 0) -> InferenceEngine:
        """The engine for ``cfg``, building (and possibly evicting) on miss.

        A miss reuses any cached plan for the same (network, input_size,
        compute_dtype), so an evicted-and-rebuilt engine — or a variant
        differing only in param storage — skips tuning, straight to jit.

        The slow build (tune + jit) runs under a per-key lock, not the
        global one: a first request for network B never stalls behind
        network A's multi-second build, and two racing builders of the
        same key still dedupe to one engine.
        """
        key = engine_key(cfg)
        with self._lock:
            eng = self._engines.get(key)
            if eng is not None:
                self.hits += 1
                self._engines.move_to_end(key)
                return eng
            build_lock = self._build_locks.setdefault(key, threading.Lock())
        with build_lock:
            with self._lock:
                eng = self._engines.get(key)
                if eng is not None:  # lost the race: the engine exists now
                    self.hits += 1
                    self._engines.move_to_end(key)
                    return eng
                pkey = plan_key(cfg)
                plan = self._plans.get(pkey)
            eng, degraded = self._build(cfg, params=params, seed=seed,
                                        plan=plan)
            with self._lock:
                self.misses += 1
                if degraded:
                    self.degraded += 1
                    self._degraded_keys.add(key)
                else:
                    self._plans.setdefault(pkey, eng.plan)
                self._engines[key] = eng
                self._evict_locked()
                self._build_locks.pop(key, None)
            return eng

    def _build(self, cfg, *, params, seed, plan):
        """Build one engine with the resilience policy: transient build
        failures retry with capped backoff; a *persistent* failure while
        deploying a cached plan (the block-plan-deploy case) falls back
        to the xla-only plan — degraded, but serving — instead of
        failing every request for the key. Returns (engine, degraded)."""
        attempt = 0
        while True:
            try:
                if self._faults is not None:
                    delay = self._faults.check("build")
                    if delay:
                        time.sleep(delay)
                    if plan is not None:
                        self._faults.check("plan_deploy")
                return InferenceEngine(cfg, params=params, seed=seed,
                                       plan=plan,
                                       tune_mode=self.tune_mode), False
            except Exception as e:
                if isinstance(e, TransientFailure) \
                        and attempt < self.retry.max_retries:
                    with self._lock:
                        self.build_retries += 1
                    time.sleep(self.retry.delay(attempt))
                    attempt += 1
                    continue
                if plan is not None:
                    log.warning(
                        "plan deploy for %s failed persistently (%s); "
                        "rebuilding on the xla fallback plan", cfg.name, e)
                    return InferenceEngine(cfg, params=params, seed=seed,
                                           plan=xla_fallback_plan(cfg)), True
                raise

    def degrade(self, cfg, *, params=None, seed: int = 0) -> InferenceEngine:
        """Rebuild ``cfg``'s cache entry on the xla-only fallback plan —
        the degraded-mode path a batcher takes when its engine's circuit
        breaker trips on persistent tuned-kernel failures.

        The replacement keeps the old engine's params (same weights, so
        results differ only by algorithm route), takes over the cache
        slot (leases on the key keep their original engine object — a
        live stream is never yanked mid-frame), and bumps the
        ``degraded`` counter surfaced in ``stats()``.
        """
        key = engine_key(cfg)
        with self._lock:
            old = self._engines.get(key)
        if params is None and old is not None:
            params = old.params
        eng = InferenceEngine(cfg, params=params, seed=seed,
                              plan=xla_fallback_plan(cfg))
        with self._lock:
            self._engines[key] = eng
            self._engines.move_to_end(key)
            self.degraded += 1
            self._degraded_keys.add(key)
            self._evict_locked()
        log.warning("engine for %s degraded to the xla fallback plan",
                    cfg.name)
        return eng

    def lease(self, cfg, *, params=None, seed: int = 0) -> EngineLease:
        """Pin ``cfg``'s engine for a streaming session (building on miss).

        Pinned entries are exempt from eviction and from the capacity
        count; ``EngineLease.release`` unpins. Re-leasing the same key
        stacks (the entry stays pinned until every lease is released).
        """
        key = engine_key(cfg)
        while True:
            eng = self.get(cfg, params=params, seed=seed)
            with self._lock:
                # an eviction may race between get() and the pin; only
                # pin the entry if it is still the one we were handed
                if self._engines.get(key) is eng:
                    self._pins[key] = self._pins.get(key, 0) + 1
                    self.leases += 1
                    return EngineLease(self, key, eng)

    def _release(self, key: tuple) -> None:
        with self._lock:
            n = self._pins.get(key, 0) - 1
            if n > 0:
                self._pins[key] = n
            else:
                self._pins.pop(key, None)
            if key in self._engines:
                self._engines.move_to_end(key)  # back to LRU order, as MRU
            self._evict_locked()

    def _evict_locked(self) -> None:
        """Evict oldest unpinned entries until the unpinned population
        fits ``capacity`` (call with the lock held). Pinned entries ride
        outside the capacity count — they cannot be evicted, and they
        must not starve the unpinned working set either."""
        unpinned = [k for k in self._engines if not self._pins.get(k)]
        for k in unpinned[:max(0, len(unpinned) - self.capacity)]:
            del self._engines[k]
            self.evictions += 1

    def stats(self) -> dict:
        with self._lock:
            return {"capacity": self.capacity, "size": len(self._engines),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "leases": self.leases,
                    "degraded": self.degraded,
                    "degraded_keys": sorted(
                        (list(k) for k in self._degraded_keys), key=str),
                    "build_retries": self.build_retries,
                    "pinned": [k for k in self._engines if self._pins.get(k)],
                    "keys": list(self._engines)}
