"""Deterministic fault injection for the serving stack.

Chaos testing a serving tier only proves something if the chaos is
*reproducible*: the same script must produce the same retries, trips,
sheds, and degrades on every run, or the test flakes and the gate is
noise. A ``FaultInjector`` is that script: per **site**, a map from call
index (0-based, in call order) to an injected fault — an exception, a
latency spike, or both. The instrumented code calls ``check(site)`` once
per operation; the injector advances the site's counter, raises the
scripted error (if any) and returns the scripted delay in seconds.

Sites threaded through the serving stack:

  =============  =====================================================
  site           one check per...
  =============  =====================================================
  ``dispatch``   MicroBatcher dispatch *attempt* (retries re-check, so
                 "fail attempts 0 and 1, succeed on 2" is scriptable).
                 Skipped once the batcher runs a degraded engine: the
                 injected fault models a sick *tuned kernel*, and the
                 xla fallback path does not contain it.
  ``frame``      StreamSession frame execution (latency spikes add to
                 the simulated compute charge deterministically).
  ``build``      EngineCache engine-build attempt.
  ``plan_deploy``EngineCache build that deploys a cached tuning plan.
  =============  =====================================================

Scripting:

  * ``fail(site, *indices)`` / ``fail_from(site, start)`` — raise at the
    given call indices / at every index >= ``start`` (persistent fault).
  * ``delay(site, *indices, seconds=s)`` / ``delay_from(site, start,
    seconds=s)`` — inject a latency spike. Threaded callers sleep it;
    the simulated clock adds it to the compute charge (pure arithmetic,
    so deadline accounting stays deterministic).

The default error type is ``TransientFailure`` — the retryable class; a
persistent Pallas-style fault is modeled with ``error=RuntimeError`` (or
any non-transient type) plus ``fail_from``. ``log`` records every
injection as ``(site, index, kind)`` so tests can assert the script
actually fired. Counters are lock-protected; determinism additionally
needs a deterministic caller (one loop thread per site, which is how the
batcher and sessions are built).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.runtime.fault_tolerance import TransientFailure

SITES = ("dispatch", "frame", "build", "plan_deploy")


@dataclass(frozen=True)
class Fault:
    """One scripted injection: raise ``error`` (a BaseException subclass
    or instance; None = no error) and/or report ``delay_s`` seconds of
    injected latency."""

    error: object = None
    delay_s: float = 0.0
    message: str | None = None

    def raise_if_error(self, site: str, index: int) -> None:
        if self.error is None:
            return
        if isinstance(self.error, BaseException):
            raise self.error
        msg = self.message or f"injected fault at {site}[{index}]"
        raise self.error(msg)


class FaultInjector:
    """A deterministic, scripted fault plan shared across the serving
    stack (pass one injector to ``Server(faults=...)``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._at: dict[str, dict[int, Fault]] = {}     # site -> idx -> Fault
        self._from: dict[str, tuple[int, Fault]] = {}  # site -> (start, Fault)
        self._counts: dict[str, int] = {}
        self.log: list[tuple[str, int, str]] = []      # (site, idx, kind)

    # ------------------------------------------------------------------
    # scripting

    def fail(self, site: str, *indices: int, error=TransientFailure,
             message: str | None = None) -> "FaultInjector":
        """Raise ``error`` on the given call indices of ``site``."""
        with self._lock:
            for i in indices:
                self._at.setdefault(site, {})[i] = Fault(error=error,
                                                         message=message)
        return self

    def fail_from(self, site: str, start: int = 0, *, error=TransientFailure,
                  message: str | None = None) -> "FaultInjector":
        """Raise ``error`` on every call index >= ``start`` — a
        *persistent* fault (what trips the circuit breaker)."""
        with self._lock:
            self._from[site] = (start, Fault(error=error, message=message))
        return self

    def delay(self, site: str, *indices: int,
              seconds: float) -> "FaultInjector":
        """Inject a latency spike of ``seconds`` at the given indices."""
        with self._lock:
            for i in indices:
                self._at.setdefault(site, {})[i] = Fault(delay_s=seconds)
        return self

    def delay_from(self, site: str, start: int = 0, *,
                   seconds: float) -> "FaultInjector":
        """Inject ``seconds`` of latency on every call >= ``start`` (a
        fixed service-time floor — the overload bench's capacity knob)."""
        with self._lock:
            self._from[site] = (start, Fault(delay_s=seconds))
        return self

    def clear(self, site: str | None = None) -> "FaultInjector":
        """Drop the script (one site, or everything); counters survive."""
        with self._lock:
            sites = [site] if site is not None else \
                list(self._at.keys() | self._from.keys())
            for s in sites:
                self._at.pop(s, None)
                self._from.pop(s, None)
        return self

    # ------------------------------------------------------------------
    # the instrumented-code side

    def check(self, site: str) -> float:
        """One operation at ``site``: advance the call counter, raise the
        scripted error if this index has one, return the scripted delay
        in seconds (0.0 when none). Callers apply the delay themselves —
        threaded code sleeps it, simulated clocks add it to the charge."""
        with self._lock:
            i = self._counts.get(site, 0)
            self._counts[site] = i + 1
            fault = self._at.get(site, {}).get(i)
            if fault is None and site in self._from:
                start, f = self._from[site]
                if i >= start:
                    fault = f
            if fault is None:
                return 0.0
            kind = ("error" if fault.error is not None else "delay")
            self.log.append((site, i, kind))
        fault.raise_if_error(site, i)
        return fault.delay_s

    def count(self, site: str) -> int:
        """How many times ``site`` has been checked so far."""
        with self._lock:
            return self._counts.get(site, 0)

    def stats(self) -> dict:
        with self._lock:
            return {"counts": dict(self._counts),
                    "injected": len(self.log)}
