"""Asyncio client for the serving wire protocol.

``AsyncClient`` speaks the length-prefixed framing from
``serving/protocol.py`` over one socket connection and multiplexes any
number of concurrent ``classify`` awaits onto it: each request carries a
client-assigned id, a background reader task matches result frames back
to their waiting futures, so responses can (and do) arrive in completion
order rather than submit order — the whole point of the server's
continuous batching.

Typed rejections travel as status codes and re-raise client-side as the
same exceptions an in-process caller sees (``Overloaded``,
``DeadlineExceeded``, ``CircuitOpen``; malformed requests raise
``BadRequest``, server-side dispatch failures ``RemoteError``). A dropped
connection fails every pending await with ``ConnectionError`` — a client
coroutine never hangs on a dead socket.

    client = await AsyncClient.connect(*endpoint.address)
    logits = await client.classify("resnet18", image,
                                   options=RequestOptions(deadline_ms=50))
    await client.close()

Logits come back bitwise-equal to ``engine.run`` on the same image: the
wire carries float32 both ways and the server's batcher preserves the
sequential contract (``tests/test_protocol.py`` asserts end-to-end).
"""
from __future__ import annotations

import asyncio
import itertools

from repro.serving.protocol import (
    MAX_FRAME_BYTES,
    ProtocolError,
    decode_response,
    encode_request,
    error_for,
    unpack_body,
)


class AsyncClient:
    """One connection to a ``ServerEndpoint``; safe for concurrent
    ``classify`` awaits from one event loop."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self._reader = reader
        self._writer = writer
        self._ids = itertools.count()
        self._pending: dict[int, asyncio.Future] = {}
        self._closed = False
        self._read_task = asyncio.get_running_loop().create_task(
            self._read_loop())

    @classmethod
    async def connect(cls, host: str, port: int) -> "AsyncClient":
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close()

    # ------------------------------------------------------------------

    async def classify(self, network: str, image, *, options=None):
        """Submit one (H, W, C) image; returns the (classes,) float32
        logits. ``options`` is a ``RequestOptions`` (dtype variant,
        deadline override, scheduler priority). Raises the same typed
        rejections an in-process ``Server.submit`` caller would see."""
        if self._closed:
            raise ConnectionError("client is closed")
        req_id = next(self._ids)
        dtype = deadline_ms = None
        priority = 0
        if options is not None:
            dtype = options.dtype
            deadline_ms = options.deadline_ms
            priority = options.priority
        future = asyncio.get_running_loop().create_future()
        self._pending[req_id] = future
        try:
            self._writer.write(encode_request(
                req_id, network, image, dtype=dtype,
                deadline_ms=deadline_ms, priority=priority))
            await self._writer.drain()
        except (OSError, ConnectionError):
            self._pending.pop(req_id, None)
            raise ConnectionError("connection to server lost") from None
        try:
            return await future
        finally:
            self._pending.pop(req_id, None)

    async def _read_loop(self) -> None:
        error: BaseException = ConnectionError(
            "connection closed by server")
        try:
            while True:
                try:
                    prefix = await self._reader.readexactly(4)
                except asyncio.IncompleteReadError as e:
                    if e.partial:
                        error = ProtocolError(
                            "connection truncated inside a length prefix")
                    break
                body_len = int.from_bytes(prefix, "big")
                if body_len > MAX_FRAME_BYTES:
                    error = ProtocolError(
                        f"length prefix {body_len} exceeds MAX_FRAME_BYTES")
                    break
                try:
                    body = await self._reader.readexactly(body_len)
                except asyncio.IncompleteReadError:
                    error = ProtocolError(
                        "connection truncated inside a frame body")
                    break
                req_id, status, message, logits = decode_response(
                    *unpack_body(body))
                future = self._pending.pop(req_id, None)
                if future is None or future.done():
                    continue  # response for a cancelled/unknown await
                if status == "ok":
                    future.set_result(logits)
                else:
                    future.set_exception(error_for(status, message))
        except (OSError, ProtocolError) as e:
            error = e
        finally:
            # never leave a coroutine hanging on a dead socket
            for future in self._pending.values():
                if not future.done():
                    future.set_exception(
                        error if isinstance(error, ProtocolError)
                        else ConnectionError(str(error)))
            self._pending.clear()

    # ------------------------------------------------------------------

    async def close(self) -> None:
        """Close the connection; pending awaits fail with
        ``ConnectionError``. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._read_task.cancel()
        try:
            await self._read_task
        except (asyncio.CancelledError, Exception):  # noqa: BLE001
            pass
        for future in self._pending.values():
            if not future.done():
                future.set_exception(ConnectionError("client closed"))
        self._pending.clear()
        try:
            self._writer.close()
            await self._writer.wait_closed()
        except (OSError, ConnectionError):
            pass
