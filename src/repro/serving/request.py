"""Serving request types — what a client submits and what it awaits.

A ``Request`` is one single-image inference in flight: the image, a
``concurrent.futures.Future`` that resolves to the logits, and timestamps
so the server can report queueing + batching latency per request. Clients
never construct these directly — ``Server.submit`` / ``MicroBatcher.submit``
do — but tests and benchmarks read the timing fields off completed ones.
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

_IDS = itertools.count()


@dataclass
class Request:
    """One single-image request: ``image`` is (H, W, C) NHWC-minus-batch;
    ``future`` resolves to the (classes,) logits (or raises the dispatch
    error). ``arrival`` is set at submit time; ``done`` when the batcher
    resolves the future — their difference is the request's full latency
    (queue wait + batching window + dispatch).

    ``deadline`` is an absolute clock value (``arrival + deadline_s``,
    stamped at admission when the batcher enforces one): a request still
    queued past it is **shed at dequeue** — failed with
    ``DeadlineExceeded`` before any compute is spent. ``cancel()`` marks
    the request for the same shed path (``Server.run`` calls it when the
    client's timeout fires, so a timed-out request never burns a
    dispatch)."""

    image: object
    future: Future = field(default_factory=Future)
    arrival: float = field(default_factory=time.perf_counter)
    done: float | None = None
    deadline: float | None = None
    cancelled: bool = False
    id: int = field(default_factory=lambda: next(_IDS))

    @property
    def latency(self) -> float | None:
        """Seconds from submit to resolution; None while in flight."""
        return None if self.done is None else self.done - self.arrival

    def cancel(self) -> None:
        """Request shedding at dequeue (client gave up). Best-effort: a
        request already mid-dispatch still completes."""
        self.cancelled = True

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


def resolve(req: Request, value) -> None:
    """Stamp completion time and fulfil the future."""
    req.done = time.perf_counter()
    req.future.set_result(value)


def fail(req: Request, exc: BaseException) -> None:
    req.done = time.perf_counter()
    req.future.set_exception(exc)
