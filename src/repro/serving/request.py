"""Serving request types — what a client submits and what it awaits.

A ``Request`` is one single-image inference in flight: the image, a
``concurrent.futures.Future`` that resolves to the logits, and timestamps
so the server can report queueing + batching latency per request. Clients
never construct these directly — the front door hands back a ``Ticket``
wrapping one — but tests and benchmarks read the timing fields off
completed ones.

``RequestOptions`` is the per-call options object (the public replacement
for the deprecated ``dtype=`` kwarg sprawl): precision variant, per-request
deadline override, and scheduling priority, all frozen so a shared options
object can never be mutated mid-flight.

``Ticket`` is the one result handle. ``Server.submit`` returns it,
``Server.run`` blocks on it, and the wire endpoint resolves it into a
response frame — three call styles, one type. ``result(timeout)`` carries
the cancel-on-timeout semantics that used to live only on ``Server.run``:
a timed-out wait cancels the request so the batcher sheds it at dequeue
instead of computing logits nobody is waiting for.
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field, replace

_IDS = itertools.count()


@dataclass(frozen=True)
class RequestOptions:
    """Per-request options (frozen): ``dtype`` picks the network's
    precision variant (own engine-cache entry, dtype-keyed plan; None =
    the config's native precision), ``deadline_ms`` overrides the server's
    default shed deadline for this request alone, and ``priority`` biases
    the cross-network device scheduler (higher dispatches first)."""

    dtype: str | None = None
    deadline_ms: float | None = None
    priority: int = 0

    def merged_dtype(self, dtype: str | None) -> "RequestOptions":
        """This options object with a (deprecated-path) ``dtype`` folded
        in; rejects conflicting values rather than silently picking one."""
        if dtype is None or dtype == self.dtype:
            return self
        if self.dtype is not None:
            raise ValueError(
                f"conflicting dtypes: options.dtype={self.dtype!r} vs "
                f"dtype={dtype!r}")
        return replace(self, dtype=dtype)


@dataclass
class Request:
    """One single-image request: ``image`` is (H, W, C) NHWC-minus-batch;
    ``future`` resolves to the (classes,) logits (or raises the dispatch
    error). ``arrival`` is set at submit time; ``done`` when the batcher
    resolves the future — their difference is the request's full latency
    (queue wait + batching window + dispatch).

    ``deadline`` is an absolute clock value (``arrival + deadline_s``,
    stamped at admission when the batcher enforces one): a request still
    queued past it is **shed at dequeue** — failed with
    ``DeadlineExceeded`` before any compute is spent. ``cancel()`` marks
    the request for the same shed path (``Ticket.result`` calls it when
    its timeout fires, so a timed-out request never burns a dispatch).
    ``priority`` feeds the device scheduler's ordering key."""

    image: object
    future: Future = field(default_factory=Future)
    arrival: float = field(default_factory=time.perf_counter)
    done: float | None = None
    deadline: float | None = None
    cancelled: bool = False
    priority: int = 0
    id: int = field(default_factory=lambda: next(_IDS))

    @property
    def latency(self) -> float | None:
        """Seconds from submit to resolution; None while in flight."""
        return None if self.done is None else self.done - self.arrival

    @property
    def urgency(self) -> float:
        """The scheduler's time key: the deadline when one is set, the
        arrival otherwise — oldest-deadline-first degrades to FIFO for
        deadline-free traffic."""
        return self.arrival if self.deadline is None else self.deadline

    def cancel(self) -> None:
        """Request shedding at dequeue (client gave up). Best-effort: a
        request already mid-dispatch still completes."""
        self.cancelled = True

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


class Ticket:
    """The one result handle for a submitted request.

    ``Server.submit`` returns a Ticket; ``Server.run`` is
    ``submit(...).result(timeout)``; the wire endpoint registers a done
    callback on one; the async client awaits the same states over the
    socket. The raw ``concurrent.futures.Future`` stays an implementation
    detail (``.future`` is the escape hatch).
    """

    __slots__ = ("_request",)

    def __init__(self, request: Request):
        self._request = request

    # ------------------------------------------------------------------
    # result access

    def result(self, timeout: float | None = None):
        """Block for the logits (or re-raise the typed rejection /
        dispatch error). On timeout the request is **cancelled** before
        the ``TimeoutError`` propagates: if it is still queued, the
        batcher sheds it at dequeue instead of burning a dispatch on a
        result nobody is waiting for."""
        try:
            return self._request.future.result(timeout)
        except FutureTimeoutError:
            self.cancel()
            raise

    def exception(self, timeout: float | None = None):
        """The settled exception (None on success); does NOT cancel on
        timeout — it is the inspection hook, ``result`` is the wait."""
        return self._request.future.exception(timeout)

    def cancel(self) -> None:
        """Give up on the request: still-queued, it sheds at dequeue
        (``DeadlineExceeded``); mid-dispatch, it completes anyway."""
        self._request.cancel()

    def done(self) -> bool:
        return self._request.future.done()

    def add_done_callback(self, fn) -> None:
        """``fn(ticket)`` once the request settles (result or error) —
        what the wire endpoint uses to turn completions into frames."""
        self._request.future.add_done_callback(lambda _f: fn(self))

    # ------------------------------------------------------------------
    # latency stamps

    @property
    def id(self) -> int:
        return self._request.id

    @property
    def arrival(self) -> float:
        """Submit-time ``perf_counter`` stamp."""
        return self._request.arrival

    @property
    def done_at(self) -> float | None:
        """Resolution-time stamp; None while in flight."""
        return self._request.done

    @property
    def latency(self) -> float | None:
        """Seconds from submit to resolution; None while in flight."""
        return self._request.latency

    @property
    def future(self) -> Future:
        """The raw Future (escape hatch for executor-style composition)."""
        return self._request.future

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self.done() else "pending"
        return f"Ticket(id={self.id}, {state})"


def resolve(req: Request, value) -> None:
    """Stamp completion time and fulfil the future."""
    req.done = time.perf_counter()
    req.future.set_result(value)


def fail(req: Request, exc: BaseException) -> None:
    req.done = time.perf_counter()
    req.future.set_exception(exc)
