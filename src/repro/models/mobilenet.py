"""MobileNetV2-style net — the mobile workload the paper targets.

Inverted-residual blocks (expand 1x1 -> depthwise 3x3 -> project 1x1) built
entirely from ``repro.core.algorithms.conv2d`` sites, so the whole backbone
runs under the TuningPlan flow exactly like ``resnet.forward``: the strided
dense stem dispatches a strided ilpm/direct kernel, every pointwise site
the pointwise kernel, every depthwise site (stride 1 *and* 2 — the
depthwise kernel downsamples in-kernel) the depthwise kernel, each with its
per-layer tuned block parameters and its ReLU6/BN epilogue fused into the
kernel's output write. Zhang et al. (2020) show the depthwise/pointwise
layer types dominate mobile inference time, which is why they get their own
kernels rather than riding the dense five.

Config ``extra`` keys: ``settings`` — MobileNetV2's (t, c, n, s) rows
(expansion, out channels, repeats, first-block stride); ``stem`` / ``head``
widths; ``img`` input size; ``arch: "mobilenet"`` routes the engine here.
"""
from __future__ import annotations

import jax

from repro.models.resnet import _conv, _conv_spec
from repro.models.spec import ParamSpec


def _dw_spec(c):
    """Depthwise 3x3: HWIO filters (3, 3, 1, C) + folded BN."""
    return {"w": ParamSpec((3, 3, 1, c), (None, None, None, None)),
            "scale": ParamSpec((c,), (None,), "ones"),
            "bias": ParamSpec((c,), (None,), "zeros")}


def _blocks(cfg):
    """Yield (name, cin, mid, cout, stride) per inverted-residual block."""
    cin = cfg.extra["stem"]
    for si, (t, c, n, s) in enumerate(cfg.extra["settings"]):
        for bi in range(n):
            yield (f"s{si}b{bi}", cin, cin * t, c, s if bi == 0 else 1)
            cin = c


def model_specs(cfg):
    sp = {"stem": _conv_spec(3, 3, 3, cfg.extra["stem"])}
    for name, cin, mid, cout, _ in _blocks(cfg):
        block = {}
        if mid != cin:  # t == 1 blocks skip the expansion conv
            block["pw1"] = _conv_spec(1, 1, cin, mid)
        block["dw"] = _dw_spec(mid)
        block["pw2"] = _conv_spec(1, 1, mid, cout)
        sp[name] = block
        last = cout
    sp["head"] = _conv_spec(1, 1, last, cfg.extra["head"])
    sp["fc"] = {"w": ParamSpec((cfg.extra["head"], cfg.vocab_size),
                               (None, None)),
                "b": ParamSpec((cfg.vocab_size,), (None,), "zeros")}
    return sp


def conv_specs(cfg):
    """(name, ConvSpec) per conv site, keyed like the params — the plan
    enumeration the engine tunes. Walks the exact geometry of ``forward``:
    stem 3x3 stride 2, then per block pw1 (1x1) at the incoming size,
    dw (depthwise, carries the block stride), pw2 (1x1) at the downsampled
    size; finally the 1x1 head. Every spec carries ``cfg.dtype`` — same
    precision-as-tuning-key contract as ``resnet.conv_specs``."""
    import dataclasses

    from repro.core.convspec import ConvSpec

    img = cfg.extra["img"]
    specs = [("stem", ConvSpec(h=img, w=img, c=3, k=cfg.extra["stem"],
                               stride=2))]
    size = -(-img // 2)
    for name, cin, mid, cout, stride in _blocks(cfg):
        if mid != cin:
            specs.append((f"{name}.pw1", ConvSpec(h=size, w=size, c=cin,
                                                  k=mid, r=1, s=1)))
        specs.append((f"{name}.dw", ConvSpec(h=size, w=size, c=mid, k=mid,
                                             stride=stride, groups=mid)))
        size = -(-size // stride)
        specs.append((f"{name}.pw2", ConvSpec(h=size, w=size, c=mid, k=cout,
                                              r=1, s=1)))
        last = cout
    specs.append(("head", ConvSpec(h=size, w=size, c=last,
                                   k=cfg.extra["head"], r=1, s=1)))
    return [(name, dataclasses.replace(sp, dtype=cfg.dtype))
            for name, sp in specs]


def block_specs(cfg):
    """(name, FusedBlockSpec) per inverted-residual block — the block-site
    enumeration the engine hands to ``build_plan(block_specs=...)``. Sites
    are keyed ``<block>.block`` (e.g. "s0b0.block"), disjoint from the
    per-conv keys, so a plan can carry both and the forward prefers the
    fused choice where one exists. Geometry mirrors ``conv_specs`` (the
    post-stem size walk); ``residual`` is set exactly where the forward
    adds the identity (stride 1, cin == cout); dtype stamps the key the
    same way as the conv specs.
    """
    from repro.core.convspec import FusedBlockSpec

    size = -(-cfg.extra["img"] // 2)  # post-stem (stride-2) size
    specs = []
    for name, cin, mid, cout, stride in _blocks(cfg):
        specs.append((f"{name}.block", FusedBlockSpec(
            "inverted_residual", h=size, w=size, cin=cin, mid=mid,
            cout=cout, stride=stride,
            residual=(stride == 1 and cin == cout), dtype=cfg.dtype)))
        size = -(-size // stride)
    return specs


def forward(params, cfg, images, *, algorithm="auto", plan=None,
            winograd_u=None):
    """images: (B,H,W,3) NHWC -> logits (B, classes); a single unbatched
    (H,W,3) image maps to (classes,) — same batch-dim tolerance as
    ``resnet.forward``, so the forward is mappable per element.

    `plan` maps layer names ("stem", "s0b0.dw", "s1b0.pw1", ...) to
    autotuner `Choice`s, same contract as ``resnet.forward``: a planned
    layer dispatches to its tuned algorithm with its tuned kernel params,
    overriding `algorithm`; `winograd_u` carries cached Winograd filter
    transforms per layer name. Plan lookup is trace-time Python, so a
    jitted forward bakes in per-layer dispatch. Activations are ReLU6
    (the MobileNetV2 nonlinearity), fused into each conv's epilogue;
    projection convs are linear. The strided dense stem runs the strided
    ilpm/direct kernels under the tuner, not the XLA escape hatch.

    A ``<block>.block`` plan entry (from ``build_plan(block_specs=...)``)
    overrides the block's 2-3 per-conv entries: the whole inverted
    residual — identity add included — runs as ONE fused dispatch, its
    expanded intermediate never leaving VMEM.
    """
    from repro.core import algorithms

    single = images.ndim == 3
    if single:
        images = images[None]
    images = images.astype(cfg.dtype)  # compute precision is cfg.dtype
    plan = plan or {}
    wu = winograd_u or {}
    x = _conv(params["stem"], images, 2, algorithm,
              choice=plan.get("stem"), act="relu6", u=wu.get("stem"))
    for name, cin, mid, cout, stride in _blocks(cfg):
        p = params[name]
        residual = stride == 1 and cin == cout
        bch = plan.get(f"{name}.block")
        if bch is not None:  # tuner fused this site: one dispatch, not 3
            x = algorithms.block_inverted_residual(
                x, p, bch, stride=stride, residual=residual)
            continue
        h = x
        if "pw1" in p:
            h = _conv(p["pw1"], h, 1, algorithm,
                      choice=plan.get(f"{name}.pw1"), act="relu6")
        h = _conv(p["dw"], h, stride, algorithm,
                  choice=plan.get(f"{name}.dw"), act="relu6")
        h = _conv(p["pw2"], h, 1, algorithm, choice=plan.get(f"{name}.pw2"))
        if residual:
            h = h + x
        x = h
    x = _conv(params["head"], x, 1, algorithm, choice=plan.get("head"),
              act="relu6")
    x = x.mean(axis=(1, 2))
    logits = x @ params["fc"]["w"] + params["fc"]["b"]
    return logits[0] if single else logits
