"""Decoder-only LM assembly: layer planning, segment scans, decode.

A config's layers are planned as (mixer, ffn) pairs — mixer ∈ {gqa, mla,
mamba}, ffn ∈ {dense, moe, none} — then grouped into repeating *segments*
(e.g. Jamba's 8-layer period) that run under ``jax.lax.scan`` with stacked
parameters, keeping the lowered HLO small for 36–72 layer configs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.spec import ParamSpec, stack_tree
from repro.sharding.rules import with_logical_constraint

Plan = tuple  # (mixer, ffn)


# ----------------------------------------------------------------------
# layer planning


def layer_plan(cfg) -> list[Plan]:
    plans = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.family == "hybrid":
            mixer = ("gqa" if cfg.attn_layer_period and
                     i % cfg.attn_layer_period == cfg.attn_layer_offset else "mamba")
        else:
            mixer = cfg.attn_impl
        if cfg.family == "ssm":
            ffn = "none"
        elif (cfg.num_experts and i >= cfg.first_dense_layers
              and i % cfg.moe_layer_period == cfg.moe_layer_offset):
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        plans.append((mixer, ffn))
    return plans


def segments(cfg) -> list[tuple[tuple[Plan, ...], int]]:
    """Group the layer plan into (period_body, repeat_count) segments."""
    plans = layer_plan(cfg)
    n = len(plans)
    pre = cfg.first_dense_layers
    out = [((p,), 1) for p in plans[:pre]]
    body = plans[pre:]
    if not body:
        return out
    m = len(body)
    for p in range(1, m + 1):
        if m % p == 0 and all(body[i] == body[i % p] for i in range(m)):
            out.append((tuple(body[:p]), m // p))
            return out
    out.append((tuple(body), 1))
    return out


# ----------------------------------------------------------------------
# per-layer block


def block_specs(cfg, plan: Plan):
    mixer, ffn_kind = plan
    sp = {"ln1": L.norm_spec(cfg.d_model)}
    if mixer == "gqa":
        sp["attn"] = L.gqa_specs(cfg)
    elif mixer == "mla":
        sp["attn"] = L.mla_specs(cfg)
    elif mixer == "mamba":
        sp["mamba"] = S.mamba_specs(cfg)
    if ffn_kind != "none":
        sp["ln2"] = L.norm_spec(cfg.d_model)
        sp["ffn"] = L.moe_specs(cfg) if ffn_kind == "moe" else L.ffn_specs(cfg)
    return sp


def cache_spec(cfg, plan: Plan, batch: int, max_seq: int):
    """Abstract decode-cache entry for one layer (shapes + dtype)."""
    mixer, _ = plan
    dt = jnp.dtype(cfg.dtype)
    if mixer in ("gqa",):
        kvd = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        return {"k": (kvd, dt, ("batch", "kv_seq", "kv_heads", None)),
                "v": (kvd, dt, ("batch", "kv_seq", "kv_heads", None))}
    if mixer == "mla":
        return {"c_kv": ((batch, max_seq, cfg.kv_lora_rank), dt,
                         ("batch", "kv_seq", None)),
                "k_rope": ((batch, max_seq, cfg.qk_rope_head_dim), dt,
                           ("batch", "kv_seq", None))}
    if mixer == "mamba":
        d_inner, G, N, P, H, Hg, conv_ch = S._dims(cfg)
        return {"conv": ((batch, cfg.ssm_conv_k - 1, conv_ch), dt,
                         ("batch", None, "ssm_inner")),
                "state": ((batch, G, Hg, P, N), dt,
                          ("batch", None, "ssm_heads", None, None))}
    raise ValueError(mixer)


def apply_block(p, cfg, plan: Plan, x, positions, *, mode, cache, pos,
                rules=None, mesh=None):
    """One layer. mode: train | prefill | decode. Returns (x, cache, aux)."""
    mixer, ffn_kind = plan
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps)
    new_cache = None
    if mixer == "gqa":
        if mode == "decode":
            out, new_cache = L.gqa_decode(p["attn"], cfg, h, cache, pos)
        else:
            out, (k, v) = L.gqa_attn(p["attn"], cfg, h, positions)
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
    elif mixer == "mla":
        if mode == "decode":
            out, new_cache = L.mla_decode(p["attn"], cfg, h, cache, pos)
        else:
            out, (c_kv, k_r) = L.mla_attn(p["attn"], cfg, h, positions)
            if mode == "prefill":
                new_cache = {"c_kv": c_kv, "k_rope": k_r}
    elif mixer == "mamba":
        if mode == "decode":
            out, new_cache = S.mamba_decode(p["mamba"], cfg, h, cache, pos)
        else:
            out, new_cache = S.mamba_forward(p["mamba"], cfg, h,
                                             want_cache=(mode == "prefill"))
    else:
        raise ValueError(mixer)
    x = x + out
    if ffn_kind != "none":
        h = L.apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn_kind == "moe":
            out, aux = L.moe(p["ffn"], cfg, h, rules=rules, mesh=mesh)
        else:
            out = L.ffn(p["ffn"], cfg, h)
        x = x + out
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules, mesh)
    return x, new_cache, aux


# ----------------------------------------------------------------------
# cache padding: prefill caches are written for the prompt, padded to max_seq


def _pad_cache_seq(cfg, plan, cache, max_seq):
    mixer, _ = plan
    if cache is None or mixer == "mamba":
        return cache

    def pad(a):
        s = a.shape[1]
        return jnp.pad(a, [(0, 0), (0, max_seq - s)] + [(0, 0)] * (a.ndim - 2)) \
            if s < max_seq else a
    return jax.tree.map(pad, cache)


# ----------------------------------------------------------------------
# model-level specs and forward


def model_specs(cfg):
    sp = {"embed": L.embed_specs(cfg), "ln_f": L.norm_spec(cfg.d_model)}
    for si, (body, n) in enumerate(segments(cfg)):
        subs = {f"sub{j}": block_specs(cfg, pl) for j, pl in enumerate(body)}
        sp[f"seg{si}"] = stack_tree(subs, n) if n > 1 else subs
    return sp


def cache_struct(cfg, batch: int, max_seq: int):
    """Abstract decode cache for the whole model, segment-structured."""
    out = {}
    for si, (body, n) in enumerate(segments(cfg)):
        subs = {}
        for j, pl in enumerate(body):
            entry = cache_spec(cfg, pl, batch, max_seq)
            if n > 1:
                entry = {k: ((n, *shp), dt, ("layer", *ax))
                         for k, (shp, dt, ax) in entry.items()}
            subs[f"sub{j}"] = entry
        out[f"seg{si}"] = subs
    return out


def _run_segment(p_seg, cfg, body, n, x, positions, *, mode, caches, pos,
                 rules, mesh, cache_len=0):
    """Run one segment (scan when n>1). caches: per-sub stacked trees."""
    def one_period(x, p_period, cache_period):
        new_caches = {}
        aux = jnp.zeros((), jnp.float32)
        for j, pl in enumerate(body):
            c_in = cache_period.get(f"sub{j}") if cache_period else None
            x, c_new, a = apply_block(p_period[f"sub{j}"], cfg, pl, x,
                                      positions, mode=mode, cache=c_in,
                                      pos=pos, rules=rules, mesh=mesh)
            if c_new is not None and mode == "prefill" and cache_len:
                c_new = _pad_cache_seq(cfg, pl, c_new, cache_len)
            if c_new is not None:
                new_caches[f"sub{j}"] = c_new
            aux = aux + a
        return x, new_caches, aux

    if n == 1:
        return one_period(x, p_seg, caches)

    def scan_body(carry, xs):
        x = carry
        p_period, cache_period = xs
        x, new_caches, aux = one_period(x, p_period, cache_period)
        return x, (new_caches, aux)

    from repro.models.scanutil import maybe_scan

    xs = (p_seg, caches)
    x, (new_caches, auxs) = maybe_scan(scan_body, x, xs, length=n,
                                       checkpoint=(cfg.remat == "full"))
    return x, new_caches, auxs.sum()


def forward(params, cfg, tokens, *, mode="train", prefix_embeds=None,
            rules=None, mesh=None, pos=0, caches=None, cache_len=0):
    """tokens: (B, S_text). prefix_embeds: (B, S_px, E) stub frontend output.

    mode=train   -> (logits (B,S,V), None, aux)
    mode=prefill -> (last-position logits (B,1,V), caches, aux)
    mode=decode  -> (logits (B,1,V), caches, aux); tokens (B,1)
    """
    from repro.sharding.rules import axis_rules

    with axis_rules(rules, mesh):
        return _forward(params, cfg, tokens, mode=mode,
                        prefix_embeds=prefix_embeds, rules=rules, mesh=mesh,
                        pos=pos, caches=caches, cache_len=cache_len)


def _forward(params, cfg, tokens, *, mode, prefix_embeds, rules, mesh, pos,
             caches, cache_len):
    x = L.embed(params["embed"], cfg, tokens,
                positions=_positions(tokens, pos)[..., :tokens.shape[1]]
                if cfg.pos_emb == "learned" else None)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = _positions(x, pos)
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules, mesh)

    new_caches = {}
    aux = jnp.zeros((), jnp.float32)
    for si, (body, n) in enumerate(segments(cfg)):
        seg_caches = caches.get(f"seg{si}") if caches else None
        x, c_new, a = _run_segment(params[f"seg{si}"], cfg, body, n, x,
                                   positions, mode=mode, caches=seg_caches,
                                   pos=pos, rules=rules, mesh=mesh,
                                   cache_len=cache_len)
        if c_new:
            new_caches[f"seg{si}"] = c_new
        aux = aux + a

    x = L.apply_norm(params["ln_f"], x, cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:]
    logits = L.unembed(params["embed"], cfg, x)
    logits = with_logical_constraint(logits, ("batch", "seq", "vocab_act"),
                                     rules, mesh)
    return logits, (new_caches or None), aux


def _positions(x, pos):
    B, S = x.shape[:2]
    return pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def decode_step(params, cfg, tokens, caches, pos, *, rules=None, mesh=None):
    """One decode step: tokens (B,1) int32, pos: scalar step index."""
    return forward(params, cfg, tokens, mode="decode", rules=rules,
                   mesh=mesh, pos=pos, caches=caches)
