"""Transformer building blocks — pure JAX, spec-tree parameterized.

Conventions:
  activations: (batch, seq, d_model) == logical ('batch','seq','embed')
  params: declared via ParamSpec with logical axes (see sharding/rules.py)
  every block comes as a (specs, apply) pair; apply() is pure.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec
from repro.sharding.rules import constrain

# full-score attention only below this Sq*Sk (else online-softmax chunking)
_FULL_THRESH = 2048 * 2048

# ----------------------------------------------------------------------
# small utilities


def padded_vocab(vocab: int) -> int:
    """Megatron-style vocab padding: keeps the unembed TP-shardable."""
    return (vocab + 511) // 512 * 512


def rms_norm(x, w, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(jnp.var(x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_spec(d, kind="rms"):
    if kind == "rms":
        return {"w": ParamSpec((d,), (None,), "ones")}
    return {"w": ParamSpec((d,), (None,), "ones"),
            "b": ParamSpec((d,), (None,), "zeros")}


def apply_norm(p, x, eps):
    if "b" in p:
        return layer_norm(x, p["w"], p["b"], eps)
    return rms_norm(x, p["w"], eps)


# ----------------------------------------------------------------------
# rotary position embedding (half-split / llama convention)


def rope(x, positions, theta):
    """x: (..., seq, heads, dim); positions: broadcastable to (..., seq)."""
    dim = x.shape[-1]
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# embeddings


def embed_specs(cfg):
    v = padded_vocab(cfg.vocab_size)
    sp = {"table": ParamSpec((v, cfg.d_model), ("vocab", "embed_fsdp"), "embed")}
    if cfg.pos_emb == "learned":
        sp["pos"] = ParamSpec((cfg.extra.get("max_seq", 32_768), cfg.d_model),
                              (None, "embed_fsdp"), "embed")
    if not cfg.tie_embeddings:
        sp["unembed"] = ParamSpec((cfg.d_model, v), ("embed_fsdp", "vocab"))
    return sp


def embed(p, cfg, tokens, positions=None):
    x = jnp.take(p["table"], tokens, axis=0).astype(cfg.dtype)
    if cfg.pos_emb == "learned" and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cfg.dtype)
    return x


def unembed(p, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bse,ve->bsv", x, p["table"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bse,ev->bsv", x, p["unembed"].astype(cfg.dtype))
    # mask the padding columns so they never receive probability mass
    v = logits.shape[-1]
    mask = jnp.arange(v) < cfg.vocab_size
    return jnp.where(mask, logits, jnp.finfo(logits.dtype).min)


# ----------------------------------------------------------------------
# attention core: online-softmax (chunked over KV) + plain paths
#
# Everything stays 4D (B, S, H, D). GQA expands K/V to the full head count
# (jnp.repeat on a replicated-or-small tensor) instead of the 5D grouped
# reshape: (G, Hkv) dims like (8, 8) are indivisible by a 16-way model axis
# and silently force full replication of the whole attention — the repeat
# keeps the head axis shardable and lets XLA slice locally.


def _attend_full(q, k, v, *, causal, q_pos, kv_pos, scale):
    """q: (B,Sq,H,D); k/v: (B,Sk,H,D)."""
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * scale
    scores = scores.astype(jnp.float32)
    if causal:
        m = q_pos[:, :, None] >= kv_pos[:, None, :]  # (B,Sq,Sk)
        scores = jnp.where(m[:, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)


def _attend_chunked(q, k, v, *, causal, q_pos, kv_pos, scale, chunk):
    """Online-softmax over KV chunks — never materializes (Sq, Sk) scores.

    Memory-efficient attention (Rabe&Staats / FlashAttention recurrence) in
    pure JAX; the production TPU path would swap in a Pallas flash kernel,
    but the chunked-jnp form already bounds transient memory for the 32k
    prefill shapes and lowers to the same tiled HLO structure.
    """
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    n = -(-Sk // chunk)
    pad = n * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    k = k.reshape(B, n, chunk, H, D)
    v = v.reshape(B, n, chunk, H, Dv)
    kv_pos = kv_pos.reshape(B, n, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs  # (B,chunk,H,D), (B,chunk)
        s = jnp.einsum("bshd,bthd->bhst", q, kc).astype(jnp.float32) * scale
        valid = pc[:, None, :] <= q_pos[:, :, None] if causal else \
            (pc < jnp.iinfo(jnp.int32).max)[:, None, :]
        s = jnp.where(valid[:, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)  # all-masked rows
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l, acc), None

    from repro.models.scanutil import maybe_scan

    init = (jnp.full((B, H, Sq), -jnp.inf, jnp.float32),
            jnp.zeros((B, H, Sq), jnp.float32),
            jnp.zeros((B, H, Sq, Dv), jnp.float32))
    # checkpoint=True: without it the scan saves every chunk's f32 scores
    # for backward — the full (Sq,Sk) matrix this path exists to avoid
    (m, l, acc), _ = maybe_scan(
        step, init,
        (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
         jnp.moveaxis(kv_pos, 1, 0)), checkpoint=True)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 2, 1).astype(q.dtype)  # (B,Sq,H,D)


def attention(q, k, v, *, causal, q_pos, kv_pos, chunk=2048, scale=None):
    """Attention core. q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D) with Hkv | Hq."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:  # GQA: expand KV to full heads (shardable, see above)
        G = Hq // Hkv
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if scale is None:
        scale = D ** -0.5
    if Sq * k.shape[1] <= _FULL_THRESH:
        return _attend_full(q, k, v, causal=causal, q_pos=q_pos,
                            kv_pos=kv_pos, scale=scale)
    return _attend_chunked(q, k, v, causal=causal, q_pos=q_pos,
                           kv_pos=kv_pos, scale=scale, chunk=chunk)


# ----------------------------------------------------------------------
# GQA attention block


def gqa_specs(cfg):
    E, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((E, H, D), ("embed_fsdp", "heads", None)),
        "wk": ParamSpec((E, KV, D), ("embed_fsdp", "kv_heads", None)),
        "wv": ParamSpec((E, KV, D), ("embed_fsdp", "kv_heads", None)),
        "wo": ParamSpec((H, D, E), ("heads", None, "embed_fsdp")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((H, D), ("heads", None), "zeros")
        sp["bk"] = ParamSpec((KV, D), ("kv_heads", None), "zeros")
        sp["bv"] = ParamSpec((KV, D), ("kv_heads", None), "zeros")
    return sp


def gqa_qkv(p, cfg, x, positions):
    dt = cfg.dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    k = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(dt))
    v = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # seq-parallel attention: activations sharded along Sq (falls back to
    # replication at decode where Sq == 1)
    q = constrain(q, ("batch", "seq_shard", None, None))
    k = constrain(k, ("batch", "seq_shard", None, None))
    v = constrain(v, ("batch", "seq_shard", None, None))
    return q, k, v


def gqa_attn(p, cfg, x, positions, *, causal=True, kv=None, kv_pos=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = gqa_qkv(p, cfg, x, positions)
    if kv is not None:  # cross-attention: use precomputed encoder kv
        k, v = kv
    kvp = kv_pos if kv_pos is not None else positions
    out = attention(q, k, v, causal=causal, q_pos=positions, kv_pos=kvp,
                    chunk=cfg.attn_chunk)
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cfg.dtype))
    return out, (k, v)


def _masked_cache_write(cache_arr, new, pos):
    """Write `new` (B,1,...) at sequence index `pos` via an iota mask.

    A dynamic-update-slice at a traced index on the model-sharded sequence
    axis makes GSPMD all-gather the whole cache every decode step (measured
    73.8 GiB/step/device on granite-8b decode_32k — EXPERIMENTS.md §Perf
    iter G1). The masked select is embarrassingly local under any sharding.
    """
    S = cache_arr.shape[1]
    iota = jnp.arange(S, dtype=jnp.int32).reshape(
        (1, S) + (1,) * (cache_arr.ndim - 2))
    return jnp.where(iota == pos, new.astype(cache_arr.dtype), cache_arr)


def gqa_decode(p, cfg, x, cache, pos):
    """One-token decode against a (B, Smax, KV, D) cache.

    cache: {"k","v"} + scalar write index comes from pos (same for batch).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = gqa_qkv(p, cfg, x, positions)
    # FlashDecoding-style split-KV: the single query REPLICATES over the
    # model axis and the computation follows the cache's sequence sharding.
    # Without this, q inherits head-sharding from wq and GSPMD resolves the
    # seq-vs-head conflict by replicating the whole cache in f32 (measured
    # 2 GiB x 36 layers per step — §Perf iter G2).
    q = constrain(q, ("batch", None, None, None))
    k = _masked_cache_write(cache["k"], k_new, pos)
    v = _masked_cache_write(cache["v"], v_new, pos)
    kv_pos = jnp.broadcast_to(jnp.arange(k.shape[1], dtype=jnp.int32)[None], (B, k.shape[1]))
    out = attention(q, k.astype(cfg.dtype), v.astype(cfg.dtype), causal=True,
                    q_pos=positions, kv_pos=kv_pos, chunk=cfg.attn_chunk)
    # keep the (B,1,H,D) result replicated: head-sharding demand from wo
    # must not propagate into the seq-sharded score/value path (§Perf G2)
    out = constrain(out, ("batch", None, None, None))
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cfg.dtype))
    return out, {"k": k, "v": v}


# ----------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)


def mla_specs(cfg):
    E, H = cfg.d_model, cfg.num_heads
    qk = cfg.qk_nope_head_dim
    qr = cfg.qk_rope_head_dim
    vd = cfg.v_head_dim
    L, Q = cfg.kv_lora_rank, cfg.q_lora_rank
    return {
        "w_dq": ParamSpec((E, Q), ("embed_fsdp", "q_lora")),
        "q_norm": norm_spec(Q),
        "w_uq": ParamSpec((Q, H, qk + qr), ("q_lora", "heads", None)),
        "w_dkv": ParamSpec((E, L), ("embed_fsdp", "kv_lora")),
        "kv_norm": norm_spec(L),
        "w_kr": ParamSpec((E, qr), ("embed_fsdp", None)),
        "w_uk": ParamSpec((L, H, qk), ("kv_lora", "heads", None)),
        "w_uv": ParamSpec((L, H, vd), ("kv_lora", "heads", None)),
        "wo": ParamSpec((H, vd, E), ("heads", None, "embed_fsdp")),
    }


def _mla_q(p, cfg, x, positions):
    dt = cfg.dtype
    cq = rms_norm(jnp.einsum("bse,eq->bsq", x, p["w_dq"].astype(dt)),
                  p["q_norm"]["w"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhd->bshd", cq, p["w_uq"].astype(dt))
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    dt = cfg.dtype
    c_kv = rms_norm(jnp.einsum("bse,el->bsl", x, p["w_dkv"].astype(dt)),
                    p["kv_norm"]["w"], cfg.norm_eps)
    k_r = jnp.einsum("bse,ed->bsd", x, p["w_kr"].astype(dt))
    k_r = rope(k_r[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_r


def mla_attn(p, cfg, x, positions):
    """Training / prefill MLA: decompress K,V per head (non-absorbed) and
    run the shared (chunk-capable) attention core — nope/rope folded into a
    single concatenated inner product."""
    dt = cfg.dtype
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_r = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lhd->bshd", c_kv, p["w_uk"].astype(dt))
    v = jnp.einsum("bsl,lhd->bshd", c_kv, p["w_uv"].astype(dt))
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_r[:, :, None],
                                  (B, S, H, cfg.qk_rope_head_dim))], axis=-1)
    q_cat = constrain(q_cat, ("batch", "seq_shard", None, None))
    k_cat = constrain(k_cat, ("batch", "seq_shard", None, None))
    v = constrain(v, ("batch", "seq_shard", None, None))
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    out = attention(q_cat, k_cat, v, causal=True, q_pos=positions,
                    kv_pos=positions, chunk=cfg.attn_chunk, scale=scale)
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    return out, (c_kv, k_r)


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed-matrix MLA decode: cache only (c_kv, k_rope) — 576 values
    per token, the technique's KV-cache win."""
    dt = cfg.dtype
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_new, kr_new = _mla_latent(p, cfg, x, positions)
    c_kv = _masked_cache_write(cache["c_kv"], c_new, pos)
    k_r = _masked_cache_write(cache["k_rope"], kr_new, pos)
    # absorb W_uk into q: (B,1,H,L); replicated query -> split-KV locality
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, p["w_uk"].astype(dt))
    q_lat = constrain(q_lat, ("batch", None, None, None))
    q_rope = constrain(q_rope, ("batch", None, None, None))
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, c_kv.astype(dt))
              + jnp.einsum("bshd,btd->bhst", q_rope, k_r.astype(dt))) * scale
    valid = jnp.arange(c_kv.shape[1])[None, :] <= pos
    scores = jnp.where(valid[:, None, None], scores.astype(jnp.float32), -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    lat_out = jnp.einsum("bhst,btl->bshl", w, c_kv.astype(dt))
    lat_out = constrain(lat_out, ("batch", None, None, None))
    out = jnp.einsum("bshl,lhd->bshd", lat_out, p["w_uv"].astype(dt))
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    return out, {"c_kv": c_kv, "k_rope": k_r}


# ----------------------------------------------------------------------
# FFN: SwiGLU / GELU-MLP


def ffn_specs(cfg, d_ff=None):
    E = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w1": ParamSpec((E, F), ("embed_fsdp", "d_ff")),
            "w3": ParamSpec((E, F), ("embed_fsdp", "d_ff")),
            "w2": ParamSpec((F, E), ("d_ff", "embed_fsdp")),
        }
    return {
        "w1": ParamSpec((E, F), ("embed_fsdp", "d_ff")),
        "b1": ParamSpec((F,), ("d_ff",), "zeros"),
        "w2": ParamSpec((F, E), ("d_ff", "embed_fsdp")),
        "b2": ParamSpec((E,), (None,), "zeros"),
    }


def ffn(p, cfg, x):
    dt = cfg.dtype
    if "w3" in p:
        h = jax.nn.silu(x @ p["w1"].astype(dt)) * (x @ p["w3"].astype(dt))
        return h @ p["w2"].astype(dt)
    h = jax.nn.gelu(x @ p["w1"].astype(dt) + p["b1"].astype(dt))
    return h @ p["w2"].astype(dt) + p["b2"].astype(dt)


# ----------------------------------------------------------------------
# MoE: top-k router + capacity dispatch (scatter or dense einsum)


def moe_specs(cfg):
    E, F, N = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    sp = {
        "router": ParamSpec((E, N), ("embed_fsdp", None), scale=E ** -0.5),
        "w1": ParamSpec((N, E, F), ("experts", "embed_fsdp", "moe_ff")),
        "w3": ParamSpec((N, E, F), ("experts", "embed_fsdp", "moe_ff")),
        "w2": ParamSpec((N, F, E), ("experts", "moe_ff", "embed_fsdp")),
    }
    if cfg.num_shared_experts:
        sp["shared"] = ffn_specs(cfg, d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return sp


def _expert_ffn(p, cfg, buf):
    """buf: (experts, cap, E) -> (experts, cap, E)."""
    dt = cfg.dtype
    h = jax.nn.silu(jnp.einsum("xcd,xdf->xcf", buf, p["w1"].astype(dt))
                    ) * jnp.einsum("xcd,xdf->xcf", buf, p["w3"].astype(dt))
    return jnp.einsum("xcf,xfd->xcd", h, p["w2"].astype(dt))


def moe(p, cfg, x, rules=None, mesh=None):
    """Mixture of experts over (B,S,E) activations.

    Returns (out, aux_loss). Dispatch impl:
      dense   — GShard dispatch-mask einsum (exact; small/smoke configs)
      scatter — sharding-aligned capacity dispatch (the at-scale path):
                tokens stay in their (batch=data, seq-shard=model) groups
                for routing/scatter (all local), and the single collective
                is the buffer reshard group-axis->expert-axis — exactly the
                all-to-all a hand-written expert-parallel MoE performs.
    """
    B, S, E = x.shape
    dt = cfg.dtype
    T = B * S
    k, N = cfg.top_k, cfg.num_experts
    logits = jnp.einsum("bse,ef->bsf", x,
                        p["router"].astype(cfg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): N * <f_i * P_i> — expressed as a
    # one-hot reduction (partitions cleanly; a scatter here would not)
    me = probs.mean(axis=(0, 1))
    ce = (idx[..., None] == jnp.arange(N)).astype(jnp.float32).sum(
        axis=(0, 1, 2)) / (T * k)
    aux = N * jnp.sum(me * ce)

    if cfg.moe_dispatch == "dense" or T * N <= 1 << 22:
        xf = x.reshape(T, E)
        idxf = idx.reshape(T, k)
        gatef = gate.reshape(T, k)
        cap = -(-max(int(cfg.capacity_factor * T * k / N), 1) // 8) * 8
        onehot = jax.nn.one_hot(idxf, N, dtype=jnp.int32)          # (T,k,N)
        pos = jnp.cumsum(onehot.reshape(T * k, N), axis=0).reshape(T, k, N) - 1
        pos = (pos * onehot).sum(-1)                               # (T,k)
        inside = pos < cap
        # dense dispatch tensor (T, N, cap) — exact reference path
        disp = (jax.nn.one_hot(idxf, N, dtype=dt)[..., None]
                * jax.nn.one_hot(pos, cap, dtype=dt)[:, :, None, :]
                * inside[..., None, None].astype(dt)).sum(1)
        buf = jnp.einsum("tnc,te->nce", disp, xf.astype(dt))
        out_buf = _expert_ffn(p, cfg, buf)
        gates_tn = (jax.nn.one_hot(idxf, N, dtype=jnp.float32)
                    * gatef[..., None]).sum(1)
        yf = jnp.einsum("tnc,nce,tn->te", disp, out_buf, gates_tn.astype(dt))
        y = yf.reshape(B, S, E)
    else:
        y = _moe_scatter_dispatch(p, cfg, x, idx, gate, mesh)

    if cfg.num_shared_experts:
        y = y + ffn(p["shared"], cfg, x)
    return y, aux


def _moe_scatter_dispatch(p, cfg, x, idx, gate, mesh):
    """Sort-based (MegaBlocks-style) capacity dispatch — gathers only.

    Two GSPMD facts shape this code:
      * a b-major flatten of (B->data, S->model) is inexpressible in tiled
        sharding (involuntary full remat), so S splits as (G, S_loc) with G
        inheriting the model-axis sharding and (B, G) staying as batch dims;
      * scatters whose indexed dims are sharded get replicated by the
        partitioner, so dispatch is expressed as argsort + gathers, which
        partition as purely local ops over the (B, G) batch dims.
    The single collective is the explicit buffer re-constraint from
    group-sharding to expert-sharding — the expert-parallel all-to-all.
    """
    dt = cfg.dtype
    B, S, E = x.shape
    k, N = cfg.top_k, cfg.num_experts
    G = mesh.shape.get("model", 1) if mesh is not None and not mesh.empty else 1
    if S % G:
        G = 1
    S_loc = S // G
    L = S_loc * k
    cap = max(int(cfg.capacity_factor * S_loc * k / N), 1)
    cap = -(-cap // 8) * 8

    xg = constrain(x.reshape(B, G, S_loc, E), ("batch", "seq_group", None, None))
    e_flat = idx.reshape(B, G, L)                       # expert of (tok, j)
    g_flat = gate.reshape(B, G, L)

    order = jnp.argsort(e_flat, axis=-1, stable=True)   # sorted by expert
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    counts = (e_flat[..., None] == jnp.arange(N)).astype(jnp.int32).sum(2)
    starts = jnp.cumsum(counts, axis=-1) - counts       # (B,G,N) exclusive

    # ---- dispatch: for each buffer slot (n, c), which sorted entry? ----
    slot_n = jnp.arange(N * cap, dtype=jnp.int32) // cap
    slot_c = jnp.arange(N * cap, dtype=jnp.int32) % cap
    src = jnp.take_along_axis(
        starts, jnp.broadcast_to(slot_n, (B, G, N * cap)), axis=-1) \
        + slot_c                                          # (B,G,N*cap)
    valid = slot_c[None, None] < jnp.take_along_axis(
        counts, jnp.broadcast_to(slot_n, (B, G, N * cap)), axis=-1)
    src_c = jnp.minimum(src, L - 1)
    entry = jnp.take_along_axis(order, src_c, axis=-1)   # sorted entry -> (t,j)
    tok = entry // k
    xbuf = jnp.take_along_axis(
        xg, tok[..., None], axis=2) * valid[..., None].astype(dt)
    buf = xbuf.reshape(B, G, N, cap, E)
    # the all-to-all: group-sharding -> expert-sharding
    buf = constrain(buf, ("batch", None, "experts_act", None, None))

    h = jax.nn.silu(jnp.einsum("bgxcd,xdf->bgxcf", buf, p["w1"].astype(dt))
                    ) * jnp.einsum("bgxcd,xdf->bgxcf", buf, p["w3"].astype(dt))
    out_buf = jnp.einsum("bgxcf,xfd->bgxcd", h, p["w2"].astype(dt))
    # keep expert-sharding on the einsum OUTPUT: the constraint transposes
    # onto the cotangent, so the weight-grad einsum sees both operands
    # expert-sharded (else dW materializes full-size f32 per device)
    out_buf = constrain(out_buf, ("batch", None, "experts_act", None, None))
    out_flat = out_buf.reshape(B, G, N * cap, E)
    # reverse all-to-all: back to group-sharding for the local combine
    out_flat = constrain(out_flat, ("batch", "seq_group", None, None))

    # ---- combine: each (tok, j) entry reads its slot back ----
    inv = jnp.argsort(order, axis=-1)                    # entry -> sorted pos
    rank = inv - jnp.take_along_axis(starts, e_flat, axis=-1)
    inside = rank < cap
    slot = jnp.minimum(e_flat * cap + rank, N * cap - 1)
    y_ent = jnp.take_along_axis(out_flat, slot[..., None], axis=2)
    y_ent = y_ent * (g_flat * inside.astype(jnp.float32))[..., None].astype(dt)
    y = y_ent.reshape(B, G, S_loc, k, E).sum(3)
    y = constrain(y, ("batch", "seq_group", None, None))
    return y.reshape(B, S, E).astype(dt)
