"""ResNet (paper's evaluation network) — NHWC, inference-folded BatchNorm.

Every convolution — the 7x7/2 stem, every 3x3 (strided stage entries
included), and every 1x1 (bottleneck reduce/expand, projection shortcuts)
— routes through ``repro.core.algorithms`` so the whole backbone runs
under the TuningPlan flow: no conv site is hardwired to the XLA escape
hatch. Each site passes its folded-BN scale/bias and activation into
``conv2d`` so the tuned kernel applies the epilogue inside its output
write (conv+BN+act = one HBM pass). This is the vehicle for the paper's
Fig. 5 / Tables 3-4 reproduction and the single-image inference engine
examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec


def _conv_spec(r, s, cin, cout):
    return {"w": ParamSpec((r, s, cin, cout), (None, None, None, None)),
            # folded BN: y = conv(x) * scale + bias
            "scale": ParamSpec((cout,), (None,), "ones"),
            "bias": ParamSpec((cout,), (None,), "zeros")}


def _block_specs(cin, cout, bottleneck, stride):
    if bottleneck:
        mid = cout // 4
        sp = {"c1": _conv_spec(1, 1, cin, mid),
              "c2": _conv_spec(3, 3, mid, mid),
              "c3": _conv_spec(1, 1, mid, cout)}
    else:
        sp = {"c1": _conv_spec(3, 3, cin, cout),
              "c2": _conv_spec(3, 3, cout, cout)}
    if stride != 1 or cin != cout:
        sp["proj"] = _conv_spec(1, 1, cin, cout)
    return sp


def model_specs(cfg):
    blocks = cfg.extra["blocks"]
    bottleneck = cfg.extra["bottleneck"]
    widths = [64, 128, 256, 512]
    if bottleneck:
        widths = [w * 4 for w in widths]
    sp = {"stem": _conv_spec(7, 7, 3, 64)}
    cin = 64
    for si, (n, w) in enumerate(zip(blocks, widths)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            sp[f"s{si}b{bi}"] = _block_specs(cin, w, bottleneck, stride)
            cin = w
    sp["fc"] = {"w": ParamSpec((cin, cfg.vocab_size), (None, None)),
                "b": ParamSpec((cfg.vocab_size,), (None,), "zeros")}
    return sp


def conv_specs(cfg):
    """(name, ConvSpec) per conv site, keyed like the params — the plan
    enumeration the engine tunes.

    Walks the exact geometry of ``forward``: stem (7x7 stride 2) then
    max-pool (stride 2), then each stage's blocks — the first block of
    stages 1+ enters with stride 2 (carried by c1 for basic blocks, c2 for
    bottlenecks, and the 1x1 projection shortcut), and bottleneck stages
    tune the 3x3 at the bottleneck width (cout // 4). Every site is
    enumerated — stem, strided entries, and 1x1s included — so a tuned
    plan covers 100% of the backbone's conv sites. Every spec carries
    ``cfg.dtype``: precision is part of the tuning key, so a bf16 variant
    tunes (and caches) its own plan.
    """
    import dataclasses

    from repro.core.convspec import ConvSpec

    img = cfg.extra["img"]
    blocks = cfg.extra["blocks"]
    bottleneck = cfg.extra["bottleneck"]
    widths = [64, 128, 256, 512]
    if bottleneck:
        widths = [w * 4 for w in widths]
    specs = [("stem", ConvSpec(h=img, w=img, c=3, k=64, r=7, s=7,
                               stride=2))]
    size = img // 4  # stem stride 2, then 3x3/2 max-pool
    cin = 64
    for si, n in enumerate(blocks):
        cout = widths[si]
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            name = f"s{si}b{bi}"
            if stride != 1 or cin != cout:
                specs.append((f"{name}.proj", ConvSpec(
                    h=size, w=size, c=cin, k=cout, r=1, s=1, stride=stride)))
            if bottleneck:
                mid = cout // 4
                specs.append((f"{name}.c1", ConvSpec(
                    h=size, w=size, c=cin, k=mid, r=1, s=1)))
                specs.append((f"{name}.c2", ConvSpec(
                    h=size, w=size, c=mid, k=mid, stride=stride)))
                specs.append((f"{name}.c3", ConvSpec(
                    h=-(-size // stride), w=-(-size // stride), c=mid,
                    k=cout, r=1, s=1)))
            else:
                specs.append((f"{name}.c1", ConvSpec(
                    h=size, w=size, c=cin, k=cout, stride=stride)))
                specs.append((f"{name}.c2", ConvSpec(
                    h=-(-size // stride), w=-(-size // stride), c=cout,
                    k=cout)))
            size = -(-size // stride)  # SAME: ceil, matching the forward
            cin = cout
    return [(name, dataclasses.replace(sp, dtype=cfg.dtype))
            for name, sp in specs]


def block_specs(cfg):
    """(name, FusedBlockSpec) per residual block — the block-site
    enumeration for ``build_plan(block_specs=...)``, keyed
    ``<block>.block``. Each site is the block's *final* conv (basic c2:
    3x3, bottleneck c3: 1x1 — always stride 1, since stage-entry
    downsampling happens in the earlier conv) with the shortcut add and
    the outer ReLU fused into its output write. Geometry mirrors
    ``conv_specs``; dtype stamps the key identically."""
    from repro.core.convspec import FusedBlockSpec

    blocks = cfg.extra["blocks"]
    bottleneck = cfg.extra["bottleneck"]
    widths = [64, 128, 256, 512]
    if bottleneck:
        widths = [w * 4 for w in widths]
    size = cfg.extra["img"] // 4  # stem stride 2, then 3x3/2 max-pool
    specs = []
    for si, n in enumerate(blocks):
        cout = widths[si]
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            size = -(-size // stride)  # the final conv runs post-stride
            mid = cout // 4 if bottleneck else cout
            rs = 1 if bottleneck else 3
            specs.append((f"s{si}b{bi}.block", FusedBlockSpec(
                "residual_conv", h=size, w=size, cin=mid, mid=mid,
                cout=cout, r=rs, s=rs, residual=True, dtype=cfg.dtype)))
    return specs


def _conv(p, x, stride, algorithm, padding="SAME", choice=None, act=None,
          u=None):
    """One conv site: folded-BN scale/bias and the activation ride into
    the kernel as a fused epilogue (``algorithms.conv2d`` threads them to
    the dispatched kernel's output write)."""
    from repro.core import algorithms

    return algorithms.conv2d(x, p["w"], stride=stride, padding=padding,
                             algorithm=algorithm, choice=choice,
                             scale=p["scale"], bias=p["bias"], act=act, u=u)


def _block(p, x, bottleneck, stride, algorithm, name="", plan=None, wu=None):
    """A ``<name>.block`` plan entry replaces the block's final conv AND
    the shortcut add + outer ReLU with one fused dispatch (see
    ``algorithms.block_residual_conv``); otherwise the tail runs as the
    per-layer conv followed by a separate XLA add/ReLU pass."""
    from repro.core import algorithms

    plan = plan or {}
    wu = wu or {}
    idn = x
    if "proj" in p:
        idn = _conv(p["proj"], x, stride, algorithm,
                    choice=plan.get(f"{name}.proj"))
    bch = plan.get(f"{name}.block")
    if bottleneck:
        h = _conv(p["c1"], x, 1, algorithm, choice=plan.get(f"{name}.c1"),
                  act="relu")
        h = _conv(p["c2"], h, stride, algorithm,
                  choice=plan.get(f"{name}.c2"), act="relu",
                  u=wu.get(f"{name}.c2"))
        if bch is not None:
            return algorithms.block_residual_conv(h, p["c3"], bch, res=idn)
        h = _conv(p["c3"], h, 1, algorithm, choice=plan.get(f"{name}.c3"))
    else:
        h = _conv(p["c1"], x, stride, algorithm,
                  choice=plan.get(f"{name}.c1"), act="relu",
                  u=wu.get(f"{name}.c1"))
        if bch is not None:
            return algorithms.block_residual_conv(h, p["c2"], bch, res=idn)
        h = _conv(p["c2"], h, 1, algorithm, choice=plan.get(f"{name}.c2"),
                  u=wu.get(f"{name}.c2"))
    return jax.nn.relu(h + idn)


def forward(params, cfg, images, *, algorithm="ilpm", plan=None,
            winograd_u=None):
    """images: (B,H,W,3) NHWC -> logits (B, classes); a single unbatched
    (H,W,3) image maps to (classes,).

    `algorithm` selects the conv algorithm for every conv site — the
    paper's five contenders are all valid values (plus 'xla' reference);
    1x1 sites degrade gracefully (pointwise/ilpm) and strided sites use
    the strided ilpm/direct kernels. `plan` optionally maps layer names
    ("stem", "s0b1.c2", "s1b0.proj", ...) to autotuner `Choice`s; a
    planned layer dispatches to its tuned algorithm with its tuned kernel
    parameters, overriding `algorithm`. `winograd_u` maps layer names to
    cached filter transforms `U = G g Gᵀ` (computed once per engine build
    — weights are frozen at inference). Plan lookup is trace-time Python,
    so a jitted forward bakes in per-layer dispatch.

    Batch-dim tolerance makes the forward mappable per element: under
    ``jax.vmap`` / ``lax.map`` over an image stack each element arrives
    unbatched, is promoted to a batch of one (the paper's single-image
    shape), and squeezed back on return.
    """
    single = images.ndim == 3
    if single:
        images = images[None]
    images = images.astype(cfg.dtype)  # compute precision is cfg.dtype
    plan = plan or {}
    wu = winograd_u or {}
    blocks = cfg.extra["blocks"]
    bottleneck = cfg.extra["bottleneck"]
    x = _conv(params["stem"], images, 2, algorithm,
              choice=plan.get("stem"), act="relu", u=wu.get("stem"))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, n in enumerate(blocks):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _block(params[f"s{si}b{bi}"], x, bottleneck, stride,
                       algorithm, name=f"s{si}b{bi}", plan=plan, wu=wu)
    x = x.mean(axis=(1, 2))
    logits = x @ params["fc"]["w"] + params["fc"]["b"]
    return logits[0] if single else logits
