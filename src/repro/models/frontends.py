"""Modality frontends.

Per the assignment spec the VLM/audio frontends are STUBS for the assigned
shapes — ``input_specs()`` provides precomputed frame/patch embeddings. The
real conv paths are implemented here anyway (they are where the paper's
technique lives for these archs) and are exercised by unit tests + the conv
benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec


def vit_patch_specs(cfg, patch=14, in_ch=3):
    return {"w": ParamSpec((patch, patch, in_ch, cfg.d_model),
                           (None, None, None, "embed_fsdp")),
            "b": ParamSpec((cfg.d_model,), (None,), "zeros")}


def vit_patch_embed(p, cfg, images, patch=14, algorithm="ilpm"):
    """images: (B,H,W,3) -> (B, n_patches, d_model) via stride-`patch` conv.

    A stride-p pxp conv is exactly a non-overlapping patch unroll + matmul —
    routed through the ILP-M conv engine (the paper's technique) when
    requested; the engine will pick its unit-stride path or the blocked
    matmul equivalent.
    """
    from repro.core import algorithms

    y = algorithms.conv2d(images, p["w"], stride=patch, padding="VALID",
                          algorithm=algorithm)
    B, Hp, Wp, C = y.shape
    return (y + p["b"]).reshape(B, Hp * Wp, C)


def audio_stem_specs(cfg, n_mels=80):
    return {
        "w1": ParamSpec((3, n_mels, cfg.d_model), (None, None, "embed_fsdp")),
        "b1": ParamSpec((cfg.d_model,), (None,), "zeros"),
        "w2": ParamSpec((3, cfg.d_model, cfg.d_model), (None, None, "embed_fsdp")),
        "b2": ParamSpec((cfg.d_model,), (None,), "zeros"),
    }


def audio_stem(p, cfg, mel):
    """mel: (B, T, n_mels) -> (B, T//2, d_model): whisper's 2-conv stem.

    conv1: k=3 stride 1; conv2: k=3 stride 2; GELU after each. Implemented
    with the ILP-M layout (channels-last, taps accumulated) — the 1D
    specialization of the paper's algorithm.
    """
    from repro.kernels import ops as kops

    x = jax.nn.gelu(kops.conv1d_dense(mel, p["w1"], p["b1"], stride=1))
    x = jax.nn.gelu(kops.conv1d_dense(x, p["w2"], p["b2"], stride=2))
    return x
