"""Scan wrapper: remat-aware, and unrollable for cost probes.

Two concerns meet here:

1. **Backward memory**: ``jax.lax.scan`` saves every per-iteration residual
   for the backward pass — for the chunked-attention scan that silently
   rematerializes the full (Sq, Sk) score matrix it was built to avoid.
   ``checkpoint=True`` remats the body so residuals are recomputed.

2. **Cost probes**: XLA's ``cost_analysis()`` counts a while-loop body ONCE
   regardless of trip count, so scanned programs under-report FLOPs /
   bytes / collectives. Setting ``REPRO_UNROLL_SCAN=1`` makes every
   maybe_scan a Python loop, giving exact per-op costs on small probe
   models (the dry-run extrapolates those to full depth — see
   launch/dryrun.py §cost-probes).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def unroll_mode() -> bool:
    return os.environ.get("REPRO_UNROLL_SCAN") == "1"


def maybe_scan(body, init, xs, *, length=None, checkpoint=False):
    """lax.scan(body, init, xs) with remat + unroll-probe support."""
    if checkpoint:
        body = jax.checkpoint(body)
    if not unroll_mode():
        return jax.lax.scan(body, init, xs, length=length)

    if length is None:
        length = len(jax.tree.leaves(xs)[0])
    carry = init
    ys_list = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys_list.append(y)
    if all(jax.tree.leaves(y) == [] or y is None for y in ys_list):
        ys = None
    else:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
    return carry, ys
