"""Mamba-2 (SSD — state-space duality) blocks in pure JAX.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic form +
sequential inter-chunk state pass, arXiv:2405.21060 §6); decode is the O(1)
recurrent update. The depthwise causal conv1d routes through
``repro.kernels.ops.causal_conv1d`` — the paper's ILP-M technique applied to
this architecture family (channels on lanes, taps unrolled, VMEM-pinned tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec
from repro.models.layers import norm_spec, rms_norm


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_head_dim
    H = d_inner // P
    Hg = H // G
    conv_ch = d_inner + 2 * G * N
    return d_inner, G, N, P, H, Hg, conv_ch


def mamba_specs(cfg):
    E = cfg.d_model
    d_inner, G, N, P, H, Hg, conv_ch = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return {
        "in_proj": ParamSpec((E, d_in_proj), ("embed_fsdp", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv_k, conv_ch), ("conv_k", "ssm_inner"),
                            scale=cfg.ssm_conv_k ** -0.5),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), "zeros"),  # A = -exp(0) = -1
        "D": ParamSpec((H,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros"),
        "norm": norm_spec(d_inner),
        "out_proj": ParamSpec((d_inner, E), ("ssm_inner", "embed_fsdp")),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, G, N, P, H, Hg, conv_ch = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt


def ssd_chunked(x, dt, A, Bm, C, chunk):
    """Chunked SSD scan.

    x: (B,L,G,Hg,P)  dt: (B,L,G,Hg)  A: (G,Hg) (negative)
    Bm, C: (B,L,G,N).  Returns (y (B,L,G,Hg,P), final_state (B,G,Hg,P,N)).
    """
    Bsz, L, G, Hg, P = x.shape
    N = Bm.shape[-1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Q = chunk
    xc = x.reshape(Bsz, nc, Q, G, Hg, P)
    dtc = dt.reshape(Bsz, nc, Q, G, Hg).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, G, N)
    Cc = C.reshape(Bsz, nc, Q, G, N)
    # chunk axis on 'model' (sequence parallelism through the SSD): keeps
    # the (B,nc,Q,Q,G,Hg) intra-chunk decay/score tensors sharded
    from repro.sharding.rules import constrain as _cons
    xc = _cons(xc, ("batch", "seq_shard", None, None, None, None))
    dtc = _cons(dtc, ("batch", "seq_shard", None, None, None))
    Bc = _cons(Bc, ("batch", "seq_shard", None, None, None))
    Cc = _cons(Cc, ("batch", "seq_shard", None, None, None))

    dA = dtc * A.astype(jnp.float32)          # (B,nc,Q,G,Hg), <= 0
    cum = jnp.cumsum(dA, axis=2)              # running log-decay in chunk

    # --- intra-chunk (quadratic attention-like form) ---
    # cumsums/exponents in f32 for stability; the O(L·Q) decay/score
    # tensors are then carried in the model dtype (bf16 in production) —
    # they are bounded (decays <= 1) and this halves the dominant HBM
    # traffic of the whole block (§Perf iter M5)
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None]).astype(x.dtype)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None, None]
    W = jnp.where(tri, CB[..., None] * decay * dtc[:, :, None].astype(x.dtype),
                  jnp.zeros((), x.dtype))
    y_intra = jnp.einsum("bcijgh,bcjghp->bcighp", W, xc)

    # --- per-chunk end states ---
    decay_end = jnp.exp(cum[:, :, -1:, :, :] - cum)         # (B,nc,Q,G,Hg)
    S = jnp.einsum("bcjgh,bcjgn,bcjghp->bcghpn",
                   (decay_end * dtc).astype(x.dtype), Bc, xc)

    # --- inter-chunk state pass: decay-matrix form (no scan) ---
    # A lax.scan over a model-sharded chunk axis regathers the whole
    # (B,nc,G,Hg,P,N) states tensor every iteration (measured 91 GiB per
    # layer per device — EXPERIMENTS.md §Perf iters M1/M4). The prefix
    # recurrence is instead evaluated as a tiny lower-triangular
    # (nc x nc) chunk-decay matrix contraction: O(nc^2) FMAs on per-chunk
    # states, fully parallel, one reduce over the (sharded) source-chunk
    # axis, zero re-gathers.
    a = jnp.cumsum(cum[:, :, -1], axis=1)                    # (B,nc,G,Hg)
    ld = cum[:, :, -1]
    # T_s[c, c'] = decay from end of chunk c' to start of chunk c (c' < c)
    tri_c = jnp.tril(jnp.ones((nc, nc), bool), k=-1)
    expo = a[:, :, None] - ld[:, :, None] - a[:, None]       # (B,nc,nc,G,Hg)
    T_s = jnp.where(tri_c[None, :, :, None, None], jnp.exp(expo), 0.0)
    s_start = jnp.einsum("bcdgh,bdghpn->bcghpn", T_s.astype(x.dtype), S)
    # final state: inclusive decay to the end of the last chunk
    T_f = jnp.exp(a[:, -1:] - a)                             # (B,nc,G,Hg)
    s_final = jnp.einsum("bdgh,bdghpn->bghpn", T_f.astype(x.dtype), S)

    y_inter = jnp.einsum("bcign,bcghpn,bcigh->bcighp",
                         Cc, s_start, jnp.exp(cum).astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, nc * Q, G, Hg, P)
    return y[:, :L], s_final


def mamba_forward(p, cfg, xres, *, want_cache=False):
    """Full-sequence Mamba-2 mixer. xres: (B,L,E) (already normed)."""
    from repro.kernels import ops as kops

    dt_ = cfg.dtype
    d_inner, G, N, P, H, Hg, conv_ch = _dims(cfg)
    B_, L, E = xres.shape
    zxbcdt = xres @ p["in_proj"].astype(dt_)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = kops.causal_conv1d(xBC, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :d_inner].reshape(B_, L, G, Hg, P)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B_, L, G, N)
    C = xBC[..., d_inner + G * N:].reshape(B_, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)).reshape(B_, L, G, Hg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).reshape(G, Hg)
    y, s_final = ssd_chunked(x, dt, A, Bm, C, cfg.ssd_chunk)
    y = y + p["D"].astype(dt_).reshape(G, Hg)[..., None] * x
    y = y.reshape(B_, L, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    if want_cache:
        tail = xBC_raw_tail(cfg, xres, p)  # conv window tail, pre-activation
        return out, {"conv": tail, "state": s_final}
    return out, None


def xBC_raw_tail(cfg, xres, p):
    """Last (k-1) pre-conv xBC values — the decode conv window."""
    d_inner, G, N, P, H, Hg, conv_ch = _dims(cfg)
    k = cfg.ssm_conv_k
    tail_in = xres[:, -(k - 1):]
    zxbcdt = tail_in @ p["in_proj"].astype(cfg.dtype)
    _, xBC, _ = _split_proj(cfg, zxbcdt)
    B_ = xres.shape[0]
    pad = (k - 1) - tail_in.shape[1]
    if pad > 0:
        xBC = jnp.pad(xBC, ((0, 0), (pad, 0), (0, 0)))
    return xBC


def mamba_decode(p, cfg, xres, cache, pos):
    """One-token recurrent update. cache: {conv:(B,k-1,convch),
    state:(B,G,Hg,P,N)}."""
    dt_ = cfg.dtype
    d_inner, G, N, P, H, Hg, conv_ch = _dims(cfg)
    B_ = xres.shape[0]
    zxbcdt = xres[:, 0] @ p["in_proj"].astype(dt_)       # (B, d_in_proj)
    z, xBC_new, dt = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([cache["conv"], xBC_new[:, None]], axis=1)  # (B,k,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(dt_)) \
        + p["conv_b"].astype(dt_)
    xBC = jax.nn.silu(conv_out)
    x = xBC[..., :d_inner].reshape(B_, G, Hg, P)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B_, G, N)
    C = xBC[..., d_inner + G * N:].reshape(B_, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)).reshape(B_, G, Hg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).reshape(G, Hg)

    s = cache["state"]
    dA = jnp.exp(dt * A)[..., None, None].astype(s.dtype)     # (B,G,Hg,1,1)
    upd = jnp.einsum("bgh,bgn,bghp->bghpn", dt.astype(dt_), Bm, x)
    s = s * dA + upd
    y = jnp.einsum("bgn,bghpn->bghp", C, s) \
        + p["D"].astype(dt_).reshape(G, Hg)[..., None] * x
    y = y.reshape(B_, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None]            # (B,1,E)
    new_cache = {"conv": window[:, 1:], "state": s}
    return out, new_cache
