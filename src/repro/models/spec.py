"""Parameter specification trees.

Models declare an *abstract* parameter tree of ``ParamSpec`` leaves (shape +
logical axes + initializer). From one spec tree we derive: real initialized
params (smoke tests / examples), ``jax.ShapeDtypeStruct`` stand-ins (dry-run,
no allocation), and ``NamedSharding`` trees (pjit in/out shardings).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import logical_sharding


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # stddev override; default fan-in scaled
    dtype: str | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(spec: ParamSpec, n: int) -> ParamSpec:
    """Add a leading stacked-layer dim (consumed by jax.lax.scan)."""
    return ParamSpec((n, *spec.shape), ("layer", *spec.axes), spec.init,
                     spec.scale, spec.dtype)


def stack_tree(tree, n: int):
    return jax.tree.map(lambda s: stack(s, n), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _fan_in(spec: ParamSpec) -> int:
    if len(spec.shape) == 0:
        return 1
    # convention: last axis is the output axis for 2D+ weights
    fan = int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else spec.shape[0]
    return max(fan, 1)


def init_leaf(spec: ParamSpec, key, default_dtype) -> jax.Array:
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dtype)
    scale = spec.scale if spec.scale is not None else _fan_in(spec) ** -0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def _walk(tree, path=()):
    if isinstance(tree, ParamSpec):
        yield path, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
    else:
        raise TypeError(f"bad spec node at {path}: {type(tree)}")


def init_params(spec_tree, seed: int, param_dtype: str):
    """Materialize a spec tree (CPU-sized configs only)."""
    root = jax.random.key(seed)

    def build(tree):
        if isinstance(tree, ParamSpec):
            return None
        return {k: build(v) for k, v in tree.items()}

    out = build(spec_tree)
    for path, spec in _walk(spec_tree):
        key = root
        for p in path:
            key = jax.random.fold_in(key, hash(p) % (2**31))
        node = out
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = init_leaf(spec, key, param_dtype)
    return out if out is not None else init_leaf(spec_tree, root, param_dtype)


def abstract_params(spec_tree, param_dtype: str):
    """ShapeDtypeStruct tree — dry-run stand-ins, zero allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(spec_tree, mesh, rules):
    return jax.tree.map(
        lambda s: logical_sharding(s.axes, s.shape, rules, mesh),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(spec_tree))
