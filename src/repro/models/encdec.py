"""Encoder-decoder transformer (Whisper-style).

Encoder consumes stub frame embeddings (the conv frontend is a STUB per the
assignment spec; the real conv stem lives in models/frontends.py). Decoder
blocks: causal self-attention + cross-attention + MLP, pre-LN, learned
positions, tied unembedding — the whisper-base block structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.spec import ParamSpec, stack_tree
from repro.sharding.rules import with_logical_constraint


def _enc_block_specs(cfg):
    return {"ln1": L.norm_spec(cfg.d_model, "ln"),
            "attn": L.gqa_specs(cfg),
            "ln2": L.norm_spec(cfg.d_model, "ln"),
            "ffn": L.ffn_specs(cfg)}


def _dec_block_specs(cfg):
    return {"ln1": L.norm_spec(cfg.d_model, "ln"),
            "attn": L.gqa_specs(cfg),
            "lnx": L.norm_spec(cfg.d_model, "ln"),
            "xattn": L.gqa_specs(cfg),
            "ln2": L.norm_spec(cfg.d_model, "ln"),
            "ffn": L.ffn_specs(cfg)}


def model_specs(cfg):
    v = L.padded_vocab(cfg.vocab_size)
    return {
        "embed": {
            "table": ParamSpec((v, cfg.d_model), ("vocab", "embed_fsdp"), "embed"),
            "pos": ParamSpec((cfg.extra.get("max_seq", 32_768), cfg.d_model),
                             (None, "embed_fsdp"), "embed"),
        },
        "enc_pos": ParamSpec((cfg.encoder_seq, cfg.d_model),
                             (None, "embed_fsdp"), "embed"),
        "enc": stack_tree(_enc_block_specs(cfg), cfg.num_encoder_layers),
        "enc_ln": L.norm_spec(cfg.d_model, "ln"),
        "dec": stack_tree(_dec_block_specs(cfg), cfg.num_layers),
        "dec_ln": L.norm_spec(cfg.d_model, "ln"),
    }


def encode(params, cfg, frames, *, rules=None, mesh=None):
    """frames: (B, T_enc, E) stub embeddings -> encoder states."""
    x = frames.astype(cfg.dtype) + params["enc_pos"][None, : frames.shape[1]].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                           x.shape[:2])

    def body(x, p):
        h = L.apply_norm(p["ln1"], x, cfg.norm_eps)
        out, _ = L.gqa_attn(p["attn"], cfg, h, pos, causal=False)
        x = x + out
        h = L.apply_norm(p["ln2"], x, cfg.norm_eps)
        x = x + L.ffn(p["ffn"], cfg, h)
        x = with_logical_constraint(x, ("batch", "seq", "embed"), rules, mesh)
        return x, None

    from repro.models.scanutil import maybe_scan

    x, _ = maybe_scan(body, x, params["enc"],
                      checkpoint=(cfg.remat == "full"))
    return L.apply_norm(params["enc_ln"], x, cfg.norm_eps)


def cross_kv(params, cfg, enc_out):
    """Precompute per-layer cross-attention K,V from encoder output."""
    def one(p):
        dt = cfg.dtype
        k = jnp.einsum("bse,ehd->bshd", enc_out, p["xattn"]["wk"].astype(dt))
        v = jnp.einsum("bse,ehd->bshd", enc_out, p["xattn"]["wv"].astype(dt))
        return {"xk": k, "xv": v}
    from repro.models.scanutil import maybe_scan

    _, out = maybe_scan(lambda c, p: (c, one(p)), 0, params["dec"])
    return out


def _dec_block(p, cfg, x, positions, enc_kv, enc_pos, *, mode, cache, pos,
               rules, mesh):
    new_cache = {}
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        out, sc = L.gqa_decode(p["attn"], cfg, h, cache, pos)
        new_cache.update(sc)
    else:
        out, (k, v) = L.gqa_attn(p["attn"], cfg, h, positions)
        new_cache.update({"k": k, "v": v})
    x = x + out
    h = L.apply_norm(p["lnx"], x, cfg.norm_eps)
    out, _ = L.gqa_attn(p["xattn"], cfg, h, positions, causal=False,
                        kv=(enc_kv["xk"], enc_kv["xv"]), kv_pos=enc_pos)
    x = x + out
    h = L.apply_norm(p["ln2"], x, cfg.norm_eps)
    x = x + L.ffn(p["ffn"], cfg, h)
    x = with_logical_constraint(x, ("batch", "seq", "embed"), rules, mesh)
    return x, new_cache


def forward(params, cfg, tokens, frames, *, mode="train", caches=None,
            pos=0, cache_len=0, rules=None, mesh=None):
    """tokens: (B,S) decoder ids; frames: (B,T_enc,E) stub embeddings.

    train   -> (logits (B,S,V), None, 0)
    prefill -> (last logits, caches{self k/v padded + cross kv}, 0)
    decode  -> (logits (B,1,V), caches, 0); frames ignored (cross kv cached)
    """
    from repro.sharding.rules import axis_rules

    with axis_rules(rules, mesh):
        return _forward(params, cfg, tokens, frames, mode=mode,
                        caches=caches, pos=pos, cache_len=cache_len,
                        rules=rules, mesh=mesh)


def _forward(params, cfg, tokens, frames, *, mode, caches, pos, cache_len,
             rules, mesh):
    B, S = tokens.shape
    positions = pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.dtype)
    x = x + jnp.take(params["embed"]["pos"], positions, axis=0).astype(cfg.dtype)

    if mode == "decode":
        enc_kv_all = caches["cross"]
        T_enc = enc_kv_all["xk"].shape[2]
    else:
        enc_out = encode(params, cfg, frames, rules=rules, mesh=mesh)
        enc_kv_all = cross_kv(params, cfg, enc_out)
        T_enc = enc_out.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(T_enc, dtype=jnp.int32)[None], (B, T_enc))

    def body(x, xs):
        p, enc_kv, cache = xs
        x, new_cache = _dec_block(p, cfg, x, positions, enc_kv, enc_pos,
                                  mode=mode, cache=cache, pos=pos,
                                  rules=rules, mesh=mesh)
        if mode == "prefill" and cache_len:
            new_cache = {k: jnp.pad(v, [(0, 0), (0, cache_len - v.shape[1]),
                                        (0, 0), (0, 0)])
                         for k, v in new_cache.items()}
        return x, new_cache

    from repro.models.scanutil import maybe_scan

    self_caches = caches.get("self") if caches else None
    x, new_self = maybe_scan(body, x, (params["dec"], enc_kv_all, self_caches),
                             checkpoint=(cfg.remat == "full"
                                         and mode == "train"))
    x = L.apply_norm(params["dec_ln"], x, cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:]
    logits = jnp.einsum("bse,ve->bsv", x, params["embed"]["table"].astype(cfg.dtype))
    v = logits.shape[-1]
    logits = jnp.where(jnp.arange(v) < cfg.vocab_size, logits,
                       jnp.finfo(logits.dtype).min)
    logits = with_logical_constraint(logits, ("batch", "seq", "vocab_act"),
                                     rules, mesh)
    new_caches = None
    if mode != "train":
        new_caches = {"self": new_self, "cross": enc_kv_all}
    return logits, new_caches, jnp.zeros((), jnp.float32)


def cache_struct(cfg, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    n, ne = cfg.num_layers, cfg.num_encoder_layers
    kvd = (n, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    xkvd = (n, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    ax = ("layer", "batch", "kv_seq", "kv_heads", None)
    xax = ("layer", "batch", None, "kv_heads", None)
    return {"self": {"k": (kvd, dt, ax), "v": (kvd, dt, ax)},
            "cross": {"xk": (xkvd, dt, xax), "xv": (xkvd, dt, xax)}}
