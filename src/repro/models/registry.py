"""Model registry: per-family dispatch + analytic parameter counting."""
from __future__ import annotations

import numpy as np

from repro.models import encdec, lm, mobilenet, resnet
from repro.models import spec as pspec


def cnn_module(cfg):
    """The CNN family module (forward / model_specs / conv_specs) for a
    config — ``extra["arch"]`` routes; ResNet is the default."""
    return mobilenet if cfg.extra.get("arch") == "mobilenet" else resnet


def model_specs(cfg):
    if cfg.family == "cnn":
        return cnn_module(cfg).model_specs(cfg)
    if cfg.is_encoder_decoder:
        return encdec.model_specs(cfg)
    return lm.model_specs(cfg)


def forward_fn(cfg):
    if cfg.family == "cnn":
        return cnn_module(cfg).forward
    if cfg.is_encoder_decoder:
        return encdec.forward
    return lm.forward


def cache_struct(cfg, batch, max_seq):
    if cfg.is_encoder_decoder:
        return encdec.cache_struct(cfg, batch, max_seq)
    return lm.cache_struct(cfg, batch, max_seq)


def count_params(cfg, active_only: bool = False) -> int:
    """Parameter count from the spec tree; `active_only` counts only the
    routed experts a token actually visits (MODEL_FLOPS for MoE)."""
    if cfg.family == "cnn":
        return pspec.count(cnn_module(cfg).model_specs(cfg))
    tree = model_specs(cfg)
    total = pspec.count(tree)
    if active_only and cfg.num_experts:
        per_expert = cfg.d_model * cfg.moe_d_ff * 3
        n_moe_layers = sum(1 for _, f in lm.layer_plan(cfg) if f == "moe")
        total -= (cfg.num_experts - cfg.top_k) * per_expert * n_moe_layers
    return total
