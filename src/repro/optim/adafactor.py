"""Adafactor-style optimizer: factored second moment + bf16 momentum.

The HBM-fitting choice for the 236B/398B configs: the v statistics of an
(A, B) matrix cost A+B instead of A*B (Shazeer & Stern, arXiv:1804.04235),
so params+opt-state ≈ 6 bytes/param instead of AdamW's 12.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(params, state_dtype="bfloat16"):
    dt = jnp.dtype(state_dtype)

    def vrow(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape) \
            else jnp.zeros(p.shape, jnp.float32)

    def vcol(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if _factored(p.shape) else jnp.zeros((0,), jnp.float32)

    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
            "step": jnp.zeros((), jnp.int32)}


def update(grads, state, params, *, lr, b1=0.9, decay=0.99, eps=1e-30,
           weight_decay=0.0, clip_threshold=1.0):
    step = state["step"] + 1

    def upd(g, m, vr, vc, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p.shape):
            vr32 = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc32 = decay * vc + (1 - decay) * g2.mean(axis=-2)
            rfac = jax.lax.rsqrt(
                vr32 / jnp.maximum(vr32.mean(axis=-1, keepdims=True), eps))
            cfac = jax.lax.rsqrt(vc32)
            u = g32 * rfac[..., None] * cfac[..., None, :]
        else:
            vr32 = decay * vr + (1 - decay) * g2
            vc32 = vc
            u = g32 * jax.lax.rsqrt(vr32)
        # update clipping (RMS of update <= threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * u
        newp = p.astype(jnp.float32) - lr * (
            m32 + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(m.dtype), vr32, vc32

    out = jax.tree.map(upd, grads, state["m"], state["vr"], state["vc"], params)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "vr": pick(2), "vc": pick(3), "step": step}


def state_specs(param_specs, state_dtype="bfloat16"):
    from repro.models.spec import ParamSpec

    def mom(s):
        return ParamSpec(s.shape, s.axes, "zeros", dtype=state_dtype)

    def vrow(s):
        if _factored(s.shape):
            return ParamSpec(s.shape[:-1], s.axes[:-1], "zeros", dtype="float32")
        return ParamSpec(s.shape, s.axes, "zeros", dtype="float32")

    def vcol(s):
        if _factored(s.shape):
            return ParamSpec(s.shape[:-2] + s.shape[-1:],
                             s.axes[:-2] + s.axes[-1:], "zeros", dtype="float32")
        return ParamSpec((0,), (None,), "zeros", dtype="float32")

    is_spec = lambda x: isinstance(x, ParamSpec)
    return {"m": jax.tree.map(mom, param_specs, is_leaf=is_spec),
            "vr": jax.tree.map(vrow, param_specs, is_leaf=is_spec),
            "vc": jax.tree.map(vcol, param_specs, is_leaf=is_spec),
            "step": ParamSpec((), (), "zeros", dtype="int32")}
