"""AdamW with dtype-configurable moment states (pure JAX, no optax).

At the 200B+ scale the moment dtype is an HBM-budget lever (DESIGN.md §5):
m/v in bf16 halve the optimizer footprint at negligible quality cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, state_dtype="float32"):
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
           weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}


def state_specs(param_specs, state_dtype="float32"):
    """ParamSpec tree for the optimizer state (sharded like the params)."""
    from repro.models.spec import ParamSpec

    def mom(s):
        return ParamSpec(s.shape, s.axes, "zeros", dtype=state_dtype)

    is_spec = lambda x: isinstance(x, ParamSpec)
    return {"m": jax.tree.map(mom, param_specs, is_leaf=is_spec),
            "v": jax.tree.map(mom, param_specs, is_leaf=is_spec),
            "step": ParamSpec((), (), "zeros", dtype="int32")}
