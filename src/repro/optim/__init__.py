from repro.optim import adafactor, adamw, compression, schedule  # noqa: F401


def get(name: str):
    return {"adamw": adamw, "adafactor": adafactor}[name]
