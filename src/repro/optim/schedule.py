"""LR schedules + gradient utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps, floor=0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((t - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(t < warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm
