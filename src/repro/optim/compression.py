"""Error-feedback int8 gradient compression for cross-pod all-reduce.

Distributed-optimization trick for the DCN-bound multi-pod mesh: gradients
are quantized to int8 with a per-tensor scale before the pod-axis all-reduce
(8x fewer bytes over the slow inter-pod links), and the quantization residual
is fed back into the next step (error feedback keeps SGD convergence —
Karimireddy et al., arXiv:1901.09847).

Implemented with shard_map over the 'pod' axis so the collective is explicit
and the quantization happens on the wire-adjacent side. Within a pod the
usual full-precision psum runs over the 'data' axis first.

The quantize/dequantize core lives in ``repro.quant`` (shared with the
inference engines' per-channel int8 weight path); this module keeps the
error-feedback + collective machinery and re-exports the primitives for
backward compatibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import dequantize, quantize  # noqa: F401  (re-export)

try:  # newer JAX exposes shard_map at the top level (check_vma kwarg)
    from jax import shard_map as _shard_map
    _REPLICATION_KWARG = "check_vma"
except ImportError:  # pragma: no cover - depends on installed JAX
    from jax.experimental.shard_map import shard_map as _shard_map
    _REPLICATION_KWARG = "check_rep"
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-tolerant shard_map with replication checking disabled."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_REPLICATION_KWARG: False})


def ef_compress(g, err):
    """Error-feedback compression: returns (codes, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    codes, scale = quantize(corrected)
    new_err = corrected - dequantize(codes, scale)
    return codes, scale, new_err


def compressed_psum_pod(grads, err_state, mesh):
    """All-reduce `grads` over the 'pod' axis with int8 wire format.

    grads: pytree already reduced within the pod (data axis). err_state:
    matching pytree of fp32 residuals. Returns (reduced grads, new errs).
    """
    if "pod" not in mesh.axis_names:
        return grads, err_state

    def one(g, e):
        def body(g_loc, e_loc):
            codes, scale, new_err = ef_compress(g_loc, e_loc)
            # int8 codes cross the DCN; scales are scalar and cheap
            summed = jax.lax.psum(codes.astype(jnp.int32), "pod")
            scale_sum = jax.lax.psum(scale, "pod")  # conservative joint scale
            npods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
            out = summed.astype(jnp.float32) * (scale_sum / npods)
            return out.astype(g_loc.dtype), new_err

        spec = P()  # per-pod replicated view of this tensor shard
        return shard_map(body, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec))(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_e = tdef.unflatten([o[1] for o in outs])
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
