from repro.sharding.rules import (  # noqa: F401
    DEFAULT_RULES,
    Rules,
    logical_sharding,
    logical_spec,
    rules_for,
    with_logical_constraint,
)
