"""Logical-axis sharding rules (MaxText-style).

Every tensor in the framework is annotated with *logical* axis names
(``('batch','seq','embed')`` …). A rule table maps logical names to mesh
axes; ``logical_spec`` resolves them to a ``PartitionSpec``, dropping any
mesh axis that does not evenly divide the concrete dimension (e.g. 8 KV
heads on a 16-way model axis fall back to replication, Megatron-style).

Mesh axes:
  pod    — across TPU pods (DCN / optical): pure data parallelism
  data   — within-pod data parallel + FSDP parameter sharding
  model  — tensor / expert / sequence parallelism
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tuple = try in order, first divisible wins;
# list-of-axes value means shard jointly over those mesh axes)
Rules = dict[str, tuple]

DEFAULT_RULES: Rules = {
    # --- activations ---
    "batch": (("pod", "data"),),          # joint shard over pod+data
    "seq": (None,),                        # replicated by default
    "seq_shard": ("model",),              # sequence parallelism opt-in
    "kv_seq": ("model",),                 # KV-cache length (split-KV decode)
    "embed": (None,),
    "heads_act": ("model",),              # activation head dim
    "vocab_act": ("model",),
    "experts_act": ("model",),
    "seq_group": ("model",),              # MoE dispatch groups (seq shards)
    # --- parameters ---
    "vocab": ("model",),
    "embed_fsdp": ("data",),              # FSDP: weight's embed dim over data
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_ff": ("model",),
    "experts": ("model",),
    "moe_ff": (None,),
    "kv_lora": (None,),
    "q_lora": (None,),
    "conv_k": (None,),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (None,),
    "ssm_groups": (None,),
    "layer": (None,),                      # stacked-scan leading dim
    None: (None,),
}


def rules_for(cfg, mesh: Mesh) -> Rules:
    """Per-config rule table: param-sharding policy + mesh-aware tweaks."""
    rules = dict(DEFAULT_RULES)
    if cfg.param_sharding == "tp":
        rules["embed_fsdp"] = (None,)
    elif cfg.param_sharding == "replicated":
        for k in ("embed_fsdp", "vocab", "heads", "kv_heads", "d_ff",
                  "experts", "ssm_inner", "ssm_heads"):
            rules[k] = (None,)
    if "pod" not in mesh.axis_names:
        rules["batch"] = (("data",),)
    # sequence parallelism on residuals/logits: default ON for the big
    # train/prefill shapes (decode S=1 is indivisible -> auto-replicated)
    if bool(cfg.extra.get("sequence_parallel", True)):
        rules["seq"] = ("model",)
    return rules


def _resolve(axis_name, dim: int, rules: Rules, mesh: Mesh):
    """Logical axis -> mesh axis (or None), honoring divisibility."""
    for cand in rules.get(axis_name, (None,)):
        if cand is None:
            return None
        axes = cand if isinstance(cand, tuple) else (cand,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            continue
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if dim % total == 0 and dim > 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def logical_spec(logical_axes, shape, rules: Rules, mesh: Mesh) -> P:
    """PartitionSpec for a tensor with the given logical axes + shape."""
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    out = []
    for ax, dim in zip(logical_axes, shape):
        res = _resolve(ax, dim, rules, mesh)
        flat = res if isinstance(res, tuple) else (res,)
        if res is not None and any(a in used for a in flat):
            res = None  # a mesh axis may appear once per spec
        if res is not None:
            used.update(flat)
        out.append(res)
    return P(*out)


def logical_sharding(logical_axes, shape, rules: Rules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(logical_axes, shape, rules, mesh))


def with_logical_constraint(x, logical_axes, rules: Rules | None, mesh: Mesh | None):
    """Annotate intermediate activations; no-op outside a mesh context."""
    if rules is None or mesh is None or mesh.empty:
        return x
    spec = logical_spec(logical_axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------
# trace-time context: lets deeply nested layer code add constraints
# without threading (rules, mesh) through every signature.

import contextlib
import threading

_CTX = threading.local()


@contextlib.contextmanager
def axis_rules(rules: Rules | None, mesh: Mesh | None):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (rules, mesh) if rules is not None and mesh is not None else None
    try:
        yield
    finally:
        _CTX.val = prev


def constrain(x, logical_axes):
    """Sharding-constrain `x` under the ambient axis_rules context."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    rules, mesh = ctx
    return with_logical_constraint(x, logical_axes, rules, mesh)
