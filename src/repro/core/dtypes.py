"""Precision rules for the whole convspec → autotune → kernels pipeline.

One module owns every dtype fact the repo needs, so element-size
accounting can never drift between ``ConvSpec``, the cost model, and the
benchmarks again (the seed hand-rolled ``2 if "16" in dtype else 4`` in
three places — and mis-sized int8 as 4 bytes in all of them):

  * ``element_size(dtype)`` — bytes per element of the *stored/streamed*
    tensors (images, filters, outputs). This is what HBM-traffic and VMEM
    working-set estimates scale with, and why dtype is a real tuning
    axis: halving the element width halves every byte term of the
    roofline, which can flip the winning algorithm per site.
  * ``ACC_DTYPE`` / ``ACC_BYTES`` — the accumulator rule. Every kernel
    accumulates in fp32 regardless of the input dtype (Lavin & Gray:
    fp16-class arithmetic holds accuracy when accumulation stays wide;
    on TPU ``preferred_element_type=float32`` is also what the MXU
    natively does for bf16 inputs) and casts on the single output write.
    Cost-model VMEM terms therefore charge accumulators at ``ACC_BYTES``
    even for 2-byte inputs.
  * ``tolerance(dtype)`` — the documented kernel-vs-reference parity
    bound per dtype (relative to the reference's max magnitude); the
    precision test sweeps and docs/algorithms.md quote the same table.
  * ``with_precision(cfg, dtype)`` — the one knob serving exposes: an
    ``ArchConfig`` variant whose compute *and* stored dtypes are
    ``dtype`` (mixed master/compute splits are a training concern; a
    deployed inference engine holds its params in its compute dtype).

``int8`` appears here as a *storage* width (quantized weights, wire
formats — see ``repro.quant``); compute on int8 codes happens after a
cast to the engine's float compute dtype, with the per-channel
dequantization scales folded into the fused epilogue.
"""
from __future__ import annotations

# Bytes per stored element. Keys are the canonical string names used by
# ConvSpec.dtype / ArchConfig.dtype (str(jnp.dtype(...)) agrees).
_ELEMENT_SIZES = {
    "float64": 8,
    "float32": 4,
    "int32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "uint8": 1,
}

# The accumulator rule: accumulate wide, cast once on the output write.
ACC_DTYPE = "float32"
ACC_BYTES = 4

# Dtypes the kernel families accept end-to-end (plan-tunable precisions).
KERNEL_DTYPES = ("float32", "bfloat16", "float16")

# Kernel-vs-reference parity bounds: max |y - ref| / max |ref| with the
# reference computed in fp32. With fp32 accumulation the error budget is
# one rounding of the inputs plus one of the output write, so the bound
# tracks the input mantissa (bf16: 8 bits, fp16: 11 bits), not the
# accumulation depth. docs/algorithms.md quotes this table.
_TOLERANCES = {
    "float32": 2e-5,
    "float16": 5e-3,
    "bfloat16": 3e-2,
}


def canonical(dtype) -> str:
    """Canonical string name for a dtype-like (str, np/jnp dtype, type)."""
    s = str(dtype)
    # jnp types repr as "<class 'jax.numpy.float16'>"; dtype objs as "float16"
    for name in _ELEMENT_SIZES:
        if s == name or s.endswith(f".{name}'>") or s == f"<dtype: {name}>":
            return name
    return s


def element_size(dtype) -> int:
    """Bytes per stored element — the single source of truth.

    Raises on unknown dtypes rather than guessing: a silent default is
    exactly the bug this module replaces.
    """
    name = canonical(dtype)
    try:
        return _ELEMENT_SIZES[name]
    except KeyError:
        raise ValueError(
            f"unknown dtype {dtype!r}; known: {sorted(_ELEMENT_SIZES)}"
        ) from None


def tolerance(dtype) -> float:
    """Documented kernel-vs-fp32-reference relative tolerance."""
    return _TOLERANCES[canonical(dtype)]


def with_precision(cfg, dtype):
    """An ``ArchConfig`` variant running (and storing params) in ``dtype``.

    The serving precision knob: ``Server.submit(net, img, dtype=...)`` and
    ``Server.open_stream(net, dtype=...)`` route through this, giving the
    variant its own engine-cache entry and its own tuning plan (byte
    traffic — and therefore the optimal algorithm — changes with element
    width, so plans are keyed by dtype too).
    """
    name = canonical(dtype)
    if name not in KERNEL_DTYPES:
        raise ValueError(
            f"unsupported engine precision {dtype!r}; "
            f"kernel dtypes: {KERNEL_DTYPES} "
            f"(int8 is a storage format — see repro.quant)")
    if cfg.dtype == name and cfg.param_dtype == name:
        return cfg
    return cfg.replace(dtype=name, param_dtype=name)
