"""ConvSpec: the key the autotuner and algorithm registry dispatch on.

``dtype`` is a first-class axis of the key: byte-traffic terms scale with
``repro.core.dtypes.element_size``, so a bf16 spec costs (and may tune)
differently from the same geometry in fp32, and two specs differing only
in dtype are distinct tuning keys.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dtypes import element_size


@dataclass(frozen=True)
class ConvSpec:
    h: int
    w: int
    c: int
    k: int
    r: int = 3
    s: int = 3
    stride: int = 1
    batch: int = 1
    dtype: str = "float32"
    groups: int = 1  # feature groups; groups == c == k is depthwise

    def __post_init__(self):
        assert self.c % self.groups == 0, (self.c, self.groups)
        assert self.k % self.groups == 0, (self.k, self.groups)

    @property
    def c_per_group(self) -> int:
        """Input channels each output channel convolves (filter depth)."""
        return self.c // self.groups

    @property
    def depthwise(self) -> bool:
        """One filter column per input channel: groups == c, k = M·c for an
        integer channel multiplier M >= 1 (lax HWIO convention)."""
        return self.groups > 1 and self.groups == self.c \
            and self.k % self.c == 0

    @property
    def channel_multiplier(self) -> int:
        """Output channels per input channel of a depthwise conv (M)."""
        assert self.depthwise, self
        return self.k // self.c

    @property
    def out_h(self):
        return -(-self.h // self.stride)  # SAME: ceil(h / stride)

    @property
    def out_w(self):
        return -(-self.w // self.stride)

    @property
    def flops(self) -> int:
        """Useful MACs x2 (SAME padding): each of the k output channels
        contracts only its group's c/groups input channels."""
        return 2 * self.batch * self.out_h * self.out_w * self.r * self.s \
            * self.c_per_group * self.k

    @property
    def element_size(self) -> int:
        """Bytes per stored element (shared rule — int8 counts as 1, not
        the 4 the seed's hand-rolled ``2 if "16" in dtype`` gave it)."""
        return element_size(self.dtype)

    @property
    def bytes_min(self) -> int:
        """Compulsory traffic: image in + filters in + output out."""
        el = self.element_size
        return el * (self.batch * self.h * self.w * self.c
                     + self.r * self.s * self.c_per_group * self.k
                     + self.batch * self.out_h * self.out_w * self.k)

    @property
    def epilogue_bytes(self) -> int:
        """Extra HBM traffic an *unfused* scale/bias/act pass costs: one
        read + one write of the conv output. Fused kernels pay ~none (the
        (k,) scale/bias vectors are noise); the cost model charges this to
        the XLA escape hatch when the call site wants an epilogue."""
        return 2 * self.element_size * self.batch * self.out_h \
            * self.out_w * self.k

    @classmethod
    def from_tensors(cls, x, w, stride):
        """Derive the spec from real tensors (NHWC image, HWIO filters).

        Group-aware: grouped filters carry ``c // groups`` channels on their
        input axis (depthwise weights are ``(r, s, 1, c)``), so ``groups`` is
        recovered as the ratio of image channels to filter depth rather than
        misreading the filter depth as the full input width.
        """
        b, h, ww, c = x.shape
        r, s, c_per_group, k = w.shape
        assert c % c_per_group == 0, (
            f"image channels {c} not divisible by filter depth {c_per_group}")
        return cls(h=h, w=ww, c=c, k=k, r=r, s=s, stride=stride, batch=b,
                   dtype=str(x.dtype), groups=c // c_per_group)
