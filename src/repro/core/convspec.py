"""ConvSpec: the key the autotuner and algorithm registry dispatch on."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ConvSpec:
    h: int
    w: int
    c: int
    k: int
    r: int = 3
    s: int = 3
    stride: int = 1
    batch: int = 1
    dtype: str = "float32"

    @property
    def out_h(self):
        return self.h // self.stride

    @property
    def out_w(self):
        return self.w // self.stride

    @property
    def flops(self) -> int:
        """Useful MACs x2 (stride-1 SAME)."""
        return 2 * self.batch * self.out_h * self.out_w * self.r * self.s \
            * self.c * self.k

    @property
    def bytes_min(self) -> int:
        """Compulsory traffic: image in + filters in + output out."""
        el = 2 if "16" in self.dtype else 4
        return el * (self.batch * self.h * self.w * self.c
                     + self.r * self.s * self.c * self.k
                     + self.batch * self.out_h * self.out_w * self.k)

    @classmethod
    def from_tensors(cls, x, w, stride):
        b, h, ww, c = x.shape
        r, s, _, k = w.shape
        return cls(h=h, w=ww, c=c, k=k, r=r, s=s, stride=stride, batch=b,
                   dtype=str(x.dtype))
