"""ConvSpec: the key the autotuner and algorithm registry dispatch on.

``dtype`` is a first-class axis of the key: byte-traffic terms scale with
``repro.core.dtypes.element_size``, so a bf16 spec costs (and may tune)
differently from the same geometry in fp32, and two specs differing only
in dtype are distinct tuning keys.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dtypes import element_size


@dataclass(frozen=True)
class ConvSpec:
    h: int
    w: int
    c: int
    k: int
    r: int = 3
    s: int = 3
    stride: int = 1
    batch: int = 1
    dtype: str = "float32"
    groups: int = 1  # feature groups; groups == c == k is depthwise

    def __post_init__(self):
        assert self.c % self.groups == 0, (self.c, self.groups)
        assert self.k % self.groups == 0, (self.k, self.groups)

    @property
    def c_per_group(self) -> int:
        """Input channels each output channel convolves (filter depth)."""
        return self.c // self.groups

    @property
    def depthwise(self) -> bool:
        """One filter column per input channel: groups == c, k = M·c for an
        integer channel multiplier M >= 1 (lax HWIO convention)."""
        return self.groups > 1 and self.groups == self.c \
            and self.k % self.c == 0

    @property
    def channel_multiplier(self) -> int:
        """Output channels per input channel of a depthwise conv (M)."""
        assert self.depthwise, self
        return self.k // self.c

    @property
    def out_h(self):
        return -(-self.h // self.stride)  # SAME: ceil(h / stride)

    @property
    def out_w(self):
        return -(-self.w // self.stride)

    @property
    def flops(self) -> int:
        """Useful MACs x2 (SAME padding): each of the k output channels
        contracts only its group's c/groups input channels."""
        return 2 * self.batch * self.out_h * self.out_w * self.r * self.s \
            * self.c_per_group * self.k

    @property
    def element_size(self) -> int:
        """Bytes per stored element (shared rule — int8 counts as 1, not
        the 4 the seed's hand-rolled ``2 if "16" in dtype`` gave it)."""
        return element_size(self.dtype)

    @property
    def bytes_min(self) -> int:
        """Compulsory traffic: image in + filters in + output out."""
        el = self.element_size
        return el * (self.batch * self.h * self.w * self.c
                     + self.r * self.s * self.c_per_group * self.k
                     + self.batch * self.out_h * self.out_w * self.k)

    @property
    def epilogue_bytes(self) -> int:
        """Extra HBM traffic an *unfused* scale/bias/act pass costs: one
        read + one write of the conv output. Fused kernels pay ~none (the
        (k,) scale/bias vectors are noise); the cost model charges this to
        the XLA escape hatch when the call site wants an epilogue."""
        return 2 * self.element_size * self.batch * self.out_h \
            * self.out_w * self.k

    @classmethod
    def from_tensors(cls, x, w, stride):
        """Derive the spec from real tensors (NHWC image, HWIO filters).

        Group-aware: grouped filters carry ``c // groups`` channels on their
        input axis (depthwise weights are ``(r, s, 1, c)``), so ``groups`` is
        recovered as the ratio of image channels to filter depth rather than
        misreading the filter depth as the full input width.
        """
        b, h, ww, c = x.shape
        r, s, c_per_group, k = w.shape
        assert c % c_per_group == 0, (
            f"image channels {c} not divisible by filter depth {c_per_group}")
        return cls(h=h, w=ww, c=c, k=k, r=r, s=s, stride=stride, batch=b,
                   dtype=str(x.dtype), groups=c // c_per_group)


@dataclass(frozen=True)
class FusedBlockSpec:
    """The tuning key for a *block-level* fused kernel candidate.

    Two kinds:

      * ``inverted_residual`` — MobileNet's expand(1x1) -> depthwise(RxS,
        stride 1|2) -> project(1x1) chain, optionally with the identity
        residual folded into the project write (``residual=True`` when
        stride == 1 and cin == cout). ``mid`` is the expanded width
        (``cin * t``); ``mid == cin`` models the t == 1 blocks that skip
        the expansion conv.
      * ``residual_conv`` — the second (stride-1) conv of a ResNet
        basic/bottleneck block with the shortcut add and the outer ReLU
        folded into its output write. ``mid`` is the conv's input width
        (``cin == mid`` by construction), ``r``/``s`` its filter size
        (3x3 for basic c2, 1x1 for bottleneck c3).

    ``h``/``w`` are the *input* spatial dims of the fused region. ``dtype``
    is part of the key exactly as for ``ConvSpec``: the saved-round-trip
    accounting scales with the element width, so a bf16 block tunes (and
    validates on deploy) separately from fp32.
    """
    kind: str
    h: int
    w: int
    cin: int
    mid: int
    cout: int
    r: int = 3
    s: int = 3
    stride: int = 1
    residual: bool = False
    batch: int = 1
    dtype: str = "float32"

    def __post_init__(self):
        assert self.kind in ("inverted_residual", "residual_conv"), self.kind
        if self.kind == "residual_conv":
            assert self.stride == 1 and self.residual, self
            assert self.cin == self.mid, self
        if self.residual and self.kind == "inverted_residual":
            assert self.stride == 1 and self.cin == self.cout, self

    @property
    def expanded(self) -> bool:
        """Whether the block has a distinct expansion conv (t > 1)."""
        return self.kind == "inverted_residual" and self.mid != self.cin

    @property
    def out_h(self) -> int:
        return -(-self.h // self.stride)  # SAME: ceil

    @property
    def out_w(self) -> int:
        return -(-self.w // self.stride)

    @property
    def element_size(self) -> int:
        return element_size(self.dtype)

    def conv_specs(self) -> tuple:
        """((name, ConvSpec), ...) — the per-layer constituents this fused
        block replaces, in execution order. The names match the model's
        ``conv_specs`` site suffixes (pw1/dw/pw2 or c2/c3) so the two
        enumerations stay cross-referenceable."""
        if self.kind == "residual_conv":
            suffix = "c2" if (self.r, self.s) != (1, 1) else "c3"
            return ((suffix, ConvSpec(
                h=self.h, w=self.w, c=self.mid, k=self.cout, r=self.r,
                s=self.s, batch=self.batch, dtype=self.dtype)),)
        parts = []
        if self.expanded:
            parts.append(("pw1", ConvSpec(
                h=self.h, w=self.w, c=self.cin, k=self.mid, r=1, s=1,
                batch=self.batch, dtype=self.dtype)))
        parts.append(("dw", ConvSpec(
            h=self.h, w=self.w, c=self.mid, k=self.mid, r=self.r, s=self.s,
            stride=self.stride, groups=self.mid, batch=self.batch,
            dtype=self.dtype)))
        parts.append(("pw2", ConvSpec(
            h=self.out_h, w=self.out_w, c=self.mid, k=self.cout, r=1, s=1,
            batch=self.batch, dtype=self.dtype)))
        return tuple(parts)

    @property
    def saved_bytes(self) -> int:
        """HBM round-trips the fusion eliminates, at the compute dtype.

        ``inverted_residual``: the expanded intermediates never leave VMEM
        — the expand output write + its (padded) depthwise read, and the
        depthwise output write + its project read. Blocks without an
        expansion conv (t == 1) only save the depthwise-output round-trip.

        ``residual_conv``: the conv-output round-trip of the separate
        shortcut-add pass (per-layer: write conv out, then read it back to
        add the identity; fused: the accumulator adds the identity before
        the single output write).
        """
        el = self.element_size
        if self.kind == "residual_conv":
            return 2 * el * self.batch * self.out_h * self.out_w * self.cout
        hp = (self.out_h - 1) * self.stride + self.r
        wp = (self.out_w - 1) * self.stride + self.s
        saved = 0
        if self.expanded:  # expand out (h*w) + padded depthwise in (hp*wp)
            saved += el * self.batch * self.mid * (self.h * self.w + hp * wp)
        # depthwise out + project in (both at the downsampled size)
        saved += 2 * el * self.batch * self.out_h * self.out_w * self.mid
        return saved

    @property
    def residual_pass_bytes(self) -> int:
        """Traffic of the *unfused* shortcut-add pass (read conv output,
        read identity, write sum) — charged to the per-layer baseline when
        ``residual`` is set, since that is what the fused write avoids."""
        if not self.residual:
            return 0
        return 3 * self.element_size * self.batch * self.out_h \
            * self.out_w * self.cout
