"""Auto-tuning library — the paper implements one for its OpenCL kernels
(§5: "we also implemented an auto-tuning library to choose the optimal
combination of the kernel parameters"); this is its TPU analogue.

Two modes:
  * cost-model (default): per-algorithm HBM-traffic + FLOP + VMEM model on
    v5e constants; picks the feasible candidate with the lowest roofline
    time max(t_compute, t_memory). Runs at trace time, no hardware needed.
  * measured: times candidates (CPU interpret mode here, real TPU wall-clock
    in production) and picks the fastest — the paper's actual procedure.

The unit of output is the **TuningPlan**: a serializable map from layer name
to (ConvSpec, Choice) covering every conv site of a network. The engine
builds one plan per network (tune once — the paper's §2.3 argument that
single-image inference amortizes per-shape tuning), saves it as JSON for
tune-once/deploy-many, and threads ``plan.choices`` into the jitted forward
so each layer dispatches to its tuned kernel with its tuned parameters.
Results are memoized per (ConvSpec, mode).
"""
from __future__ import annotations

import json
import logging
import time
from dataclasses import asdict, dataclass, field

from repro.core.convspec import ConvSpec, FusedBlockSpec
from repro.core.dtypes import ACC_BYTES

log = logging.getLogger(__name__)

# TPU v5e per-chip constants (also used by the roofline analysis)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
VMEM_BYTES = 16 * 2 ** 20  # ~16 MB usable


@dataclass(frozen=True)
class Choice:
    algorithm: str
    params: tuple  # ((name, value), ...)
    est_time: float
    est_bytes: int
    est_flops: int
    vmem: int

    def to_dict(self) -> dict:
        d = asdict(self)
        d["params"] = [list(p) for p in self.params]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Choice":
        d = dict(d)
        d["params"] = tuple((str(k), int(v)) for k, v in d["params"])
        return cls(**d)


def _el(spec):
    """Bytes per streamed element — shared rule, so dtype really moves the
    roofline (halving the width halves every byte term, which is what lets
    bf16 flip a site's winning algorithm). Accumulator terms stay ACC_BYTES
    wide regardless: kernels accumulate in fp32 and cast on the write."""
    return spec.element_size


def tunable(spec: ConvSpec) -> bool:
    """Whether a kernel family applies, i.e. the tuner has candidates.

    Three tunable classes, all covering stride 1 *and* 2 (every kernel
    family downsamples in-kernel, so strided backbone sites — the ResNet
    7x7/2 stem, stage-entry 3x3/2s, 1x1/2 projection shortcuts, MobileNet's
    strided depthwise layers — stay under the tuner):
      * dense spatial convs — the paper's five contenders at stride 1,
        the strided ilpm/direct variants at stride 2;
      * depthwise convs (groups == c, k = M·c for multiplier M >= 1);
      * dense 1x1 convs — the pointwise kernel (in-kernel subsample at
        stride 2).

    Everything else (grouped non-depthwise convs, strides > 2) runs on the
    XLA reference path; such sites still get a plan entry with an ``xla``
    Choice.
    """
    if spec.depthwise:
        return spec.stride in (1, 2)
    if spec.groups != 1:
        return False  # general grouped conv: no kernel family yet
    if spec.r == 1 and spec.s == 1:
        return spec.stride in (1, 2)
    return spec.stride in (1, 2) and spec.r > 1 and spec.s > 1


def xla_choice(spec: ConvSpec, *, peak_flops=PEAK_FLOPS,
               hbm_bw=HBM_BW, epilogue=False) -> Choice:
    """Roofline estimate for the XLA escape-hatch path (untiled model).

    With ``epilogue=True`` the site wants a scale/bias/act applied; the
    escape hatch runs it as a separate XLA pass, so it pays an extra
    read+write of the output that the fused kernels do not.
    """
    bts = spec.bytes_min + (spec.epilogue_bytes if epilogue else 0)
    t = max(spec.flops / peak_flops, bts / hbm_bw)
    return Choice("xla", (), t, bts, spec.flops, 0)


def _candidates(spec: ConvSpec, epilogue=False):
    """Enumerate (algorithm, params, hbm_bytes, flops, vmem_working_set).

    Strided specs (stride 2) enumerate only the families whose kernels
    downsample in-kernel: ilpm/direct for spatial, pointwise for 1x1,
    depthwise for grouped. ``epilogue=True`` adds the fused scale/bias
    loads (2·K elements — noise, but kept honest) to every candidate; the
    *unfused* penalty is charged to `xla_choice`, not here, since every
    kernel family fuses in-kernel.
    """
    el = _el(spec)
    B, H, W, C, K, R, S = (spec.batch, spec.out_h, spec.out_w, spec.c,
                           spec.k, spec.r, spec.s)
    stride = spec.stride
    out = B * H * W * K * el
    ep = 2 * K * el if epilogue else 0  # fused scale+bias vector loads
    P = H * W
    cands = []

    # --- depthwise: channel-slab grid, image/filter/output cut together ---
    if spec.depthwise:
        m = spec.channel_multiplier
        hp = (H - 1) * stride + R
        wp = (W - 1) * stride + S
        img = B * hp * wp * C * el
        filt = R * S * K * el
        for tc in (128, 256, 512):
            tc = min(tc, K)
            vmem = hp * wp * -(-tc // m) * el + R * S * tc * el \
                + P * tc * ACC_BYTES
            cands.append(("depthwise", (("block_c", tc),),
                          img + filt + out + ep, spec.flops, vmem))
            if tc == K:
                break
        return cands

    # --- pointwise (1x1): image resident; K-tiled grid, single tap ---
    if R == 1 and S == 1:
        img = B * spec.h * spec.w * C * el  # full image even when strided
        filt = C * K * el
        for tk in (128, 256, 512):
            tk = min(tk, K)
            vmem = (img // max(B, 1)) + C * tk * el + P * tk * ACC_BYTES
            cands.append(("pointwise", (("block_k", tk),),
                          img + filt + out + ep, spec.flops, vmem))
            if tk == K:
                break
        return cands

    hp = (H - 1) * stride + R
    wp = (W - 1) * stride + S
    img = B * hp * wp * C * el
    filt = R * S * C * K * el

    # --- ilpm: image resident; filters streamed once; K-tiled grid ---
    for tk in (128, 256, 512):
        tk = min(tk, K)
        vmem = (img // max(B, 1)) + R * S * C * tk * el + P * tk * ACC_BYTES
        cands.append(("ilpm", (("block_k", tk),), img + filt + out + ep,
                      spec.flops, vmem))
        if tk == K:
            break

    # --- direct: filters resident; image row-bands streamed ---
    for th in (4, 8, 16):
        th = min(th, H)
        bh = (th - 1) * stride + R
        band = B * -(-H // th) * bh * wp * C * el
        vmem = bh * wp * C * el + filt + th * W * K * ACC_BYTES
        cands.append(("direct", (("block_h", th),), band + filt + out + ep,
                      spec.flops, vmem))
        if th == H:
            break

    if stride != 1:
        # im2col / libdnn / winograd have no strided kernels
        return cands

    # --- im2col: patch matrix round-trips HBM (the paper's 14.6x enemy);
    # its two-phase structure can't fuse the epilogue either, so it pays
    # the full unfused output round-trip, not the ~free vector loads ---
    patches = B * P * R * S * C * el
    ep_im2col = spec.epilogue_bytes if epilogue else 0
    vmem = min(P, 256) * R * S * C * el + R * S * C * 128 * el \
        + 256 * 128 * ACC_BYTES
    cands.append(("im2col", (),
                  img + patches + patches + filt + out + ep_im2col,
                  spec.flops, vmem))

    # --- libdnn: fused; unroll redone per K tile (index-math overhead) ---
    for tk in (128, 256):
        tk = min(tk, K)
        vmem = (img // max(B, 1)) + P * R * S * C * el // max(
            -(-K // tk), 1) + R * S * C * tk * el + P * tk * ACC_BYTES
        # model the redundant unroll as extra VMEM->VMEM work: ~10% flop tax
        cands.append(("libdnn", (("block_k", tk),), img + filt + out + ep,
                      int(spec.flops * 1.10), vmem))
        if tk == K:
            break

    # --- winograd F(2,3): 2.25x fewer MACs, 4x transform traffic ---
    if (R, S) == (3, 3) and H % 2 == 0 and W % 2 == 0:
        v_bytes = B * 16 * (H // 2) * (W // 2) * C * el
        m_bytes = B * 16 * (H // 2) * (W // 2) * K * el
        traffic = img + v_bytes + v_bytes + 16 * C * K * el + m_bytes \
            + m_bytes + out + ep
        flops = 2 * B * 16 * (H // 2) * (W // 2) * C * K  # the 16 GEMMs
        vmem = (img // max(B, 1)) + 16 * C * K * el \
            + min((H // 2) * (W // 2), 512) * (C + K) * el
        cands.append(("winograd", (), traffic, flops, vmem))
    return cands


def cost_model_select(spec: ConvSpec, *, peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                      vmem_bytes=VMEM_BYTES, epilogue=False) -> Choice:
    """Roofline-model pick; peak/bw overridable to tune for other devices.

    ``epilogue=True`` costs the fused conv+BN+act variants: free for the
    kernel families (in-kernel epilogue), an extra output round-trip for
    the XLA escape hatch.
    """
    if not tunable(spec):
        return xla_choice(spec, peak_flops=peak_flops, hbm_bw=hbm_bw,
                          epilogue=epilogue)
    best = None
    for algo, params, bts, flops, vmem in _candidates(spec, epilogue):
        if vmem > vmem_bytes:
            continue
        t = max(flops / peak_flops, bts / hbm_bw)
        if best is None or t < best.est_time:
            best = Choice(algo, params, t, bts, flops, vmem)
    assert best is not None, f"no feasible algorithm for {spec}"
    return best


def _synth_inputs(spec: ConvSpec):
    """Random padded input + filters matching the spec (measured mode).

    Group-aware: the filter depth is ``c // groups`` (1 for depthwise) and
    the padded image dims follow the stride ((out-1)*stride + r; the
    stride-1 case is the familiar h + r - 1). Pointwise specs get the
    unpadded image (r == 1 makes both formulas agree).
    """
    import jax
    import jax.numpy as jnp

    dtype = jnp.dtype(spec.dtype) if spec.dtype != "float32" else jnp.float32
    hp = (spec.out_h - 1) * spec.stride + spec.r
    wp = (spec.out_w - 1) * spec.stride + spec.s
    x = jax.random.normal(jax.random.key(0),
                          (spec.batch, hp, wp, spec.c), dtype=dtype)
    w = jax.random.normal(
        jax.random.key(1),
        (spec.r, spec.s, spec.c_per_group, spec.k), dtype=dtype)
    return x, w


def measured_select(spec: ConvSpec, x=None, w=None, *, repeats=3,
                    noise_floor=0.5, epilogue=False) -> Choice:
    """Wall-clock tuning (the paper's procedure; interpret-mode here).

    ``x`` is the pre-padded input; synthesized from the spec when omitted.
    Each candidate is timed ``repeats`` times after a warm-up run and
    scored by its *minimum* (the standard low-noise estimator). Candidates
    that fail to run are logged and skipped, not silently eaten.

    Off-hardware, interpret-mode timings carry Python-dispatch noise that
    real TPU wall-clock does not, so the measured winner only displaces
    the cost model's pick when it is more than ``noise_floor`` (fraction)
    faster — the model acts as a prior under measurement noise. Set
    ``noise_floor=0`` on real hardware for pure wall-clock selection.

    Non-tunable specs short-circuit to the ``xla`` Choice without timing
    anything (there are no candidates to race). The spec's stride is
    threaded to the kernels that take it (depthwise); ``ops.dispatch``
    drops it for the stride-1-only dense kernels.
    """
    from repro.kernels import ops

    if not tunable(spec):
        return xla_choice(spec, epilogue=epilogue)
    if x is None or w is None:
        x, w = _synth_inputs(spec)

    best = None
    timed: dict[tuple, float] = {}
    for algo, params, bts, flops, vmem in _candidates(spec, epilogue):
        if vmem > VMEM_BYTES:
            continue
        try:
            ops.dispatch(algo, x, w, impl="pallas", stride=spec.stride,
                         **dict(params)).block_until_ready()  # warm-up
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                ops.dispatch(algo, x, w, impl="pallas", stride=spec.stride,
                             **dict(params)).block_until_ready()
                ts.append(time.perf_counter() - t0)
            t = min(ts)
        except Exception as e:
            log.warning("measured_select: candidate %s%r failed on %s: %s",
                        algo, dict(params), spec, e)
            continue
        timed[(algo, params)] = t
        if best is None or t < best.est_time:
            best = Choice(algo, params, t, bts, flops, vmem)
    assert best is not None, f"every candidate failed for {spec}"

    model = cost_model_select(spec, epilogue=epilogue)
    t_model = timed.get((model.algorithm, model.params))
    if t_model is not None and t_model <= best.est_time * (1 + noise_floor):
        return Choice(model.algorithm, model.params, t_model,
                      model.est_bytes, model.est_flops, model.vmem)
    return best


# ----------------------------------------------------------------------
# Block-level candidates: fused megakernels vs the per-layer chain.


def block_constituents(bspec: FusedBlockSpec, *, epilogue=True,
                       peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW):
    """The per-layer Choices the fused block competes against — one tuned
    Choice per constituent conv, costed with the same epilogue flag the
    conv sites themselves tune under (apples-to-apples)."""
    return [cost_model_select(cs, peak_flops=peak_flops, hbm_bw=hbm_bw,
                              epilogue=epilogue)
            for _, cs in bspec.conv_specs()]


def block_baseline_time(bspec: FusedBlockSpec, *, epilogue=True,
                        peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW) -> float:
    """Roofline time of the *unfused* path: the summed per-layer tuned
    choices plus — when the block carries a residual — the separate
    shortcut-add pass (a pure HBM read-modify-write the fused kernel
    folds into its output write for free)."""
    t = sum(c.est_time for c in block_constituents(
        bspec, epilogue=epilogue, peak_flops=peak_flops, hbm_bw=hbm_bw))
    return t + bspec.residual_pass_bytes / hbm_bw


def _block_candidates(bspec: FusedBlockSpec, epilogue=True,
                      peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW):
    """Enumerate (algorithm, params, hbm_bytes, flops, vmem_working_set)
    for the fused kernel at this block.

    The byte estimate is the charging rule the whole tentpole hangs on:
    the fused candidate costs exactly the per-layer constituent sum MINUS
    ``bspec.saved_bytes`` — the expanded-tensor (inverted residual) or
    conv-output (residual conv) round-trips that now stay in VMEM, at the
    block's compute dtype. The residual-conv kernel still pays one HBM
    read of the shortcut operand (it is a *different* tensor, unlike the
    inverted residual's identity, which is the already-resident input);
    per-layer, that read is part of ``residual_pass_bytes`` charged to the
    baseline instead.

    ``block_m`` slabs must divide the expanded width (a ragged slab would
    double-count the projection accumulation), enumerated LARGEST first:
    every slab width moves the same bytes, so the first feasible
    candidate wins ties and the single-slab variant — whose projection
    reduction order is bitwise-identical to the per-layer chain — is
    preferred whenever it fits VMEM.
    """
    el = bspec.element_size
    constituents = block_constituents(bspec, epilogue=epilogue,
                                      peak_flops=peak_flops, hbm_bw=hbm_bw)
    base_bytes = sum(c.est_bytes for c in constituents)
    flops = sum(c.est_flops for c in constituents)
    B = bspec.batch
    OH, OW = bspec.out_h, bspec.out_w
    P = OH * OW
    cands = []
    if bspec.kind == "residual_conv":
        bts = base_bytes - bspec.saved_bytes \
            + el * B * P * bspec.cout  # the shortcut-branch read
        hp, wp = bspec.h + bspec.r - 1, bspec.w + bspec.s - 1
        for tk in (128, 256, 512):
            tk = min(tk, bspec.cout)
            vmem = hp * wp * bspec.cin * el \
                + bspec.r * bspec.s * bspec.cin * tk * el \
                + 2 * P * tk * el + P * tk * ACC_BYTES
            cands.append(("fused_residual_conv", (("block_k", tk),),
                          bts, flops, vmem))
            if tk == bspec.cout:
                break
        return cands
    bts = base_bytes - bspec.saved_bytes
    hp = (OH - 1) * bspec.stride + bspec.r
    wp = (OW - 1) * bspec.stride + bspec.s
    if bspec.expanded:
        tms = [bspec.mid] + [t for t in (512, 256, 128)
                             if t < bspec.mid and bspec.mid % t == 0]
    else:
        tms = [bspec.mid]  # t == 1: the slab is the unsliced input
    for tm in tms:
        vmem = el * (bspec.h * bspec.w * bspec.cin + bspec.cin * tm
                     + hp * wp * tm + bspec.r * bspec.s * tm
                     + tm * bspec.cout + P * tm) \
            + ACC_BYTES * P * (tm + bspec.cout)
        cands.append(("fused_inverted_residual", (("block_m", tm),),
                      bts, flops, vmem))
    return cands


def select_block(bspec: FusedBlockSpec, mode: str = "cost_model", *,
                 epilogue=True, peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                 vmem_bytes=VMEM_BYTES):
    """Fused-vs-per-layer decision for one block site -> Choice | None.

    Returns the fused kernel's Choice when its roofline time beats the
    per-layer baseline (tuned constituents + unfused shortcut-add pass)
    AND a feasible slab width exists; ``None`` means keep the per-layer
    plan at this site. Memoized like ``select``. Block selection is
    cost-model in both modes (wall-clock racing of whole fused blocks
    needs per-stage synth weights — a measured-mode follow-up); the
    *constituent* baseline already reflects the same cost model the
    per-layer sites tuned under, so the comparison stays consistent.
    """
    assert mode in MODES, f"unknown tuning mode {mode!r}; want one of {MODES}"
    key = (bspec, "block", epilogue)
    if key in _CACHE:
        return _CACHE[key]
    best = None
    for algo, params, bts, flops, vmem in _block_candidates(
            bspec, epilogue, peak_flops=peak_flops, hbm_bw=hbm_bw):
        if vmem > vmem_bytes:
            continue
        t = max(flops / peak_flops, bts / hbm_bw)
        if best is None or t < best.est_time:
            best = Choice(algo, params, t, bts, flops, vmem)
    baseline = block_baseline_time(bspec, epilogue=epilogue,
                                   peak_flops=peak_flops, hbm_bw=hbm_bw)
    if best is not None and best.est_time >= baseline:
        best = None  # fusion saves nothing here: keep per-layer
    _CACHE[key] = best
    return best


_CACHE: dict[tuple, Choice] = {}

MODES = ("cost_model", "measured")


def select(spec: ConvSpec, mode: str = "cost_model", *, repeats=3,
           noise_floor=0.5, epilogue=False) -> Choice:
    """Memoized selection — tune once, reuse per network.

    The cache key carries the measurement settings, so e.g. a careful
    ``repeats=10, noise_floor=0`` re-tune is not served a stale quick
    result; ``epilogue`` keys too, since it shifts the cost model.
    """
    assert mode in MODES, f"unknown tuning mode {mode!r}; want one of {MODES}"
    key = (spec, mode, epilogue) if mode == "cost_model" \
        else (spec, mode, repeats, noise_floor, epilogue)
    if key not in _CACHE:
        if mode == "measured":
            _CACHE[key] = measured_select(spec, repeats=repeats,
                                          noise_floor=noise_floor,
                                          epilogue=epilogue)
        else:
            _CACHE[key] = cost_model_select(spec, epilogue=epilogue)
    return _CACHE[key]


# ----------------------------------------------------------------------
# Tuning plans: tune once offline, serialize, deploy many times.

PLAN_VERSION = 2  # v2 adds the optional "blocks" section (fused megakernels)
_READABLE_VERSIONS = (1, 2)  # v1 plans (no blocks) still deploy


@dataclass
class TuningPlan:
    """Per-layer tuned choices for one network on one device.

    ``choices`` maps layer name -> Choice and is what the model forward
    consumes for per-layer dispatch; ``specs`` keeps the ConvSpec each
    choice was tuned for (provenance + validation on reload).

    ``block_choices``/``block_specs`` are the same contract one level up:
    block-site name (``<block>.block``) -> fused-megakernel Choice /
    FusedBlockSpec. A site present here is one the tuner decided to FUSE —
    its constituent convs keep their per-layer entries in ``choices`` (so
    the same plan deploys on engines without block support), but the
    forward dispatches the single fused kernel instead.
    """
    mode: str = "cost_model"
    specs: dict[str, ConvSpec] = field(default_factory=dict)
    choices: dict[str, Choice] = field(default_factory=dict)
    block_specs: dict[str, FusedBlockSpec] = field(default_factory=dict)
    block_choices: dict[str, Choice] = field(default_factory=dict)

    def algorithms(self) -> dict[str, str]:
        return {name: ch.algorithm for name, ch in self.choices.items()}

    def block_algorithms(self) -> dict[str, str]:
        return {name: ch.algorithm
                for name, ch in self.block_choices.items()}

    def to_json(self) -> str:
        layers = {name: {"spec": asdict(self.specs[name]),
                         "choice": self.choices[name].to_dict()}
                  for name in self.specs}
        blocks = {name: {"spec": asdict(self.block_specs[name]),
                         "choice": self.block_choices[name].to_dict()}
                  for name in self.block_specs}
        return json.dumps({"version": PLAN_VERSION, "mode": self.mode,
                           "layers": layers, "blocks": blocks}, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "TuningPlan":
        d = json.loads(text)
        if d.get("version") not in _READABLE_VERSIONS:
            raise ValueError(f"unsupported plan version {d.get('version')!r}")
        plan = cls(mode=d["mode"])
        for name, layer in d["layers"].items():
            plan.specs[name] = ConvSpec(**layer["spec"])
            plan.choices[name] = Choice.from_dict(layer["choice"])
        for name, block in d.get("blocks", {}).items():  # absent in v1
            plan.block_specs[name] = FusedBlockSpec(**block["spec"])
            plan.block_choices[name] = Choice.from_dict(block["choice"])
        return plan

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "TuningPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def xla_fallback_plan(named_specs, mode: str = "cost_model") -> TuningPlan:
    """Every site on the ``xla`` escape hatch — the **degraded-mode**
    plan the serving layer deploys when tuned Pallas dispatch (or a
    block-plan deploy) fails persistently.

    Each enumerated site gets ``xla_choice`` (costed as the fused
    conv+BN+act variant, matching how engines tune), and no block sites
    are fused — the forward routes every conv through the lax reference
    path, trading the paper's tuned kernels for staying up. Geometry and
    dtype come from the same ``named_specs`` enumeration a tuned plan
    uses, so engine plan-validation accepts the fallback unchanged.
    """
    plan = TuningPlan(mode=mode)
    for name, spec in named_specs:
        plan.specs[name] = spec
        plan.choices[name] = xla_choice(spec, epilogue=True)
    return plan


def build_plan(named_specs, mode: str = "cost_model", *, repeats=3,
               noise_floor=0.5, epilogue=False,
               block_specs=None) -> TuningPlan:
    """Tune every (name, ConvSpec) pair into a TuningPlan.

    ``named_specs`` is any iterable of ``(layer_name, ConvSpec)`` — the
    engine feeds it the model's ``conv_specs`` enumeration. Each spec goes
    through ``select``, so results come from (and populate) the module's
    mode-keyed memo cache: tuning N layers that share a shape costs one
    tuning run, and repeated ``build_plan`` calls in one process are free.
    Non-tunable sites (grouped non-depthwise convs, strides > 2) still
    get a plan entry with an ``xla`` Choice — the plan covers *every*
    enumerated site, and deployment falls back per-site, never wholesale.
    ``repeats``/``noise_floor`` only matter for ``mode="measured"``;
    ``epilogue=True`` costs each site as the fused conv+BN+act variant
    (what the model forwards actually run — the engine tunes this way).

    ``block_specs`` — an optional iterable of ``(block_site_name,
    FusedBlockSpec)`` (the model's ``block_specs`` enumeration) — turns on
    block-level tuning: each site goes through ``select_block``, and only
    sites where the fused megakernel beats the per-layer baseline get a
    ``block_choices`` entry. Per-conv entries are kept for every site
    either way, so the plan stays deployable with fusion ignored.
    """
    plan = TuningPlan(mode=mode)
    for name, spec in named_specs:
        plan.specs[name] = spec
        plan.choices[name] = select(spec, mode=mode, repeats=repeats,
                                    noise_floor=noise_floor,
                                    epilogue=epilogue)
    for name, bspec in (block_specs or ()):
        choice = select_block(bspec, mode=mode, epilogue=epilogue)
        if choice is not None:
            plan.block_specs[name] = bspec
            plan.block_choices[name] = choice
    return plan
