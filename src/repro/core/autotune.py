"""Auto-tuning library — the paper implements one for its OpenCL kernels
(§5: "we also implemented an auto-tuning library to choose the optimal
combination of the kernel parameters"); this is its TPU analogue.

Two modes:
  * cost-model (default): per-algorithm HBM-traffic + FLOP + VMEM model on
    v5e constants; picks the feasible candidate with the lowest roofline
    time max(t_compute, t_memory). Runs at trace time, no hardware needed.
  * measured: times candidates (CPU interpret mode here, real TPU wall-clock
    in production) and picks the fastest — the paper's actual procedure.

Results are memoized per ConvSpec: tune once per network, then reuse — the
paper's §2.3 engineering argument that inference justifies per-shape tuning.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.convspec import ConvSpec

# TPU v5e per-chip constants (also used by the roofline analysis)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
VMEM_BYTES = 16 * 2 ** 20  # ~16 MB usable


@dataclass(frozen=True)
class Choice:
    algorithm: str
    params: tuple  # ((name, value), ...)
    est_time: float
    est_bytes: int
    est_flops: int
    vmem: int


def _el(spec):
    return 2 if "16" in spec.dtype else 4


def _candidates(spec: ConvSpec):
    """Enumerate (algorithm, params, hbm_bytes, flops, vmem_working_set)."""
    el = _el(spec)
    B, H, W, C, K, R, S = (spec.batch, spec.out_h, spec.out_w, spec.c,
                           spec.k, spec.r, spec.s)
    img = B * (H + R - 1) * (W + S - 1) * C * el
    filt = R * S * C * K * el
    out = B * H * W * K * el
    P = H * W
    cands = []

    # --- ilpm: image resident; filters streamed once; K-tiled grid ---
    for tk in (128, 256, 512):
        tk = min(tk, K)
        vmem = (img // max(B, 1)) + R * S * C * tk * el + P * tk * 4
        cands.append(("ilpm", (("block_k", tk),), img + filt + out,
                      spec.flops, vmem))
        if tk == K:
            break

    # --- direct: filters resident; image row-bands streamed ---
    for th in (4, 8, 16):
        th = min(th, H)
        band = B * -(-H // th) * (th + R - 1) * (W + S - 1) * C * el
        vmem = (th + R - 1) * (W + S - 1) * C * el + filt + th * W * K * 4
        cands.append(("direct", (("block_h", th),), band + filt + out,
                      spec.flops, vmem))
        if th == H:
            break

    # --- im2col: patch matrix round-trips HBM (the paper's 14.6x enemy) ---
    patches = B * P * R * S * C * el
    vmem = min(P, 256) * R * S * C * el + R * S * C * 128 * el + 256 * 128 * 4
    cands.append(("im2col", (), img + patches + patches + filt + out,
                  spec.flops, vmem))

    # --- libdnn: fused; unroll redone per K tile (index-math overhead) ---
    for tk in (128, 256):
        tk = min(tk, K)
        vmem = (img // max(B, 1)) + P * R * S * C * el // max(
            -(-K // tk), 1) + R * S * C * tk * el + P * tk * 4
        # model the redundant unroll as extra VMEM->VMEM work: ~10% flop tax
        cands.append(("libdnn", (("block_k", tk),), img + filt + out,
                      int(spec.flops * 1.10), vmem))
        if tk == K:
            break

    # --- winograd F(2,3): 2.25x fewer MACs, 4x transform traffic ---
    if (R, S) == (3, 3) and spec.stride == 1 and H % 2 == 0 and W % 2 == 0:
        v_bytes = B * 16 * (H // 2) * (W // 2) * C * el
        m_bytes = B * 16 * (H // 2) * (W // 2) * K * el
        traffic = img + v_bytes + v_bytes + 16 * C * K * el + m_bytes \
            + m_bytes + out
        flops = 2 * B * 16 * (H // 2) * (W // 2) * C * K  # the 16 GEMMs
        vmem = (img // max(B, 1)) + 16 * C * K * el \
            + min((H // 2) * (W // 2), 512) * (C + K) * el
        cands.append(("winograd", (), traffic, flops, vmem))
    return cands


def cost_model_select(spec: ConvSpec) -> Choice:
    best = None
    for algo, params, bts, flops, vmem in _candidates(spec):
        if vmem > VMEM_BYTES:
            continue
        t = max(flops / PEAK_FLOPS, bts / HBM_BW)
        if best is None or t < best.est_time:
            best = Choice(algo, params, t, bts, flops, vmem)
    assert best is not None, f"no feasible algorithm for {spec}"
    return best


def measured_select(spec: ConvSpec, x, w, *, repeats=3) -> Choice:
    """Wall-clock tuning (the paper's procedure; interpret-mode here)."""
    import jax
    from repro.kernels import ops

    best = None
    for algo, params, bts, flops, vmem in _candidates(spec):
        if vmem > VMEM_BYTES:
            continue
        fn = ops.ALGORITHMS[algo]
        kw = dict(params)
        try:
            y = fn(x, w, impl="pallas", **kw)
            y.block_until_ready()
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn(x, w, impl="pallas", **kw).block_until_ready()
            t = (time.perf_counter() - t0) / repeats
        except Exception:
            continue
        if best is None or t < best.est_time:
            best = Choice(algo, dict(params) and params or params, t, bts,
                          flops, vmem)
    assert best is not None
    return best


_CACHE: dict[ConvSpec, Choice] = {}


def select(spec: ConvSpec) -> Choice:
    if spec not in _CACHE:
        _CACHE[spec] = cost_model_select(spec)
    return _CACHE[spec]
