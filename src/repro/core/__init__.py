"""ILP-M convolution as a first-class framework feature.

The paper's primary contribution (instruction-level-parallelism-maximizing
convolution for single-image inference) lives here: the algorithm registry
(`conv2d`), the autotuner (the paper's §5 tuning library, TPU cost model),
the ConvSpec key, and the single-image inference engine.
"""
from repro.core.algorithms import conv2d  # noqa: F401
from repro.core.autotune import (  # noqa: F401
    Choice, TuningPlan, build_plan, cost_model_select, measured_select,
    select, select_block)
from repro.core.convspec import ConvSpec, FusedBlockSpec  # noqa: F401
from repro.core.dtypes import element_size, with_precision  # noqa: F401
from repro.core.engine import InferenceEngine  # noqa: F401
