"""Public convolution entry point with algorithm selection.

``conv2d(x, w, algorithm=...)`` is how the framework consumes the paper's
contribution: 'ilpm' | 'direct' | 'im2col' | 'libdnn' | 'winograd' run the
corresponding dense kernels; 'depthwise' | 'pointwise' run the grouped
family (MobileNet-style nets); 'auto' asks the autotuner; 'xla' is the
lax.conv_general_dilated escape hatch (grouped-but-not-depthwise convs,
strides > 2). Passing an explicit autotuner ``choice`` (a
``repro.core.autotune.Choice``) pins both the algorithm *and* its tuned
kernel parameters (``block_k``/``block_h``/``block_c``) — this is how a
TuningPlan's per-layer decisions reach the kernels.

Stride 2 stays in-kernel for every family: ilpm/direct slide strided tap
windows over the resident image (the ResNet 7x7/2 stem and stage-entry
3x3/2s), pointwise subsamples in-kernel (1x1/2 projection shortcuts), and
depthwise always downsampled in-kernel. Only im2col/libdnn/winograd are
stride-1-only; forcing one of them on a strided site falls back to ilpm.

The optional fused epilogue — ``scale``/``bias`` (folded BatchNorm, (K,)
vectors) and ``act`` ('relu' | 'relu6') — is threaded through dispatch into
the kernels, which apply it inside their output write: conv+BN+act costs
one HBM pass instead of three. The XLA escape hatch applies the identical
math as separate ops. ``u`` optionally carries a precomputed Winograd
filter transform (see ``InferenceEngine``: computed once per plan build).

Grouped convs are detected from the filter shape: HWIO filters carry
``C // groups`` channels on their input axis, so ``groups`` is the ratio of
image channels to filter depth. Depthwise (groups == C, K = M·C for any
channel multiplier M) dispatches to the depthwise kernel at stride 1 or 2;
other grouped convs fall back to the XLA reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.convspec import ConvSpec
from repro.kernels import ops, ref

# kernels that downsample in-kernel (strided tap windows / subsampling)
STRIDED_DENSE = ("ilpm", "direct")


def _auto(x, w, stride, epilogue=False):
    """Trace-time tuner lookup (memoized per ConvSpec). ``epilogue``
    matches the costing to the call: a site dispatching fused BN/act must
    be selected as its fused variant, the same way the engine's plans are
    built (``build_plan(..., epilogue=True)``)."""
    spec = ConvSpec.from_tensors(x, w, stride)
    tuned = autotune.select(spec, epilogue=epilogue)
    return tuned.algorithm, dict(tuned.params)


def conv2d(x, w, *, stride=1, padding="SAME", algorithm="auto", impl="auto",
           choice=None, scale=None, bias=None, act=None, u=None):
    """x: (B,H,W,C) NHWC; w: (R,S,C/groups,K) HWIO -> (B,H',W',K)."""
    R, S, Cg, K = w.shape
    C = x.shape[-1]
    assert C % Cg == 0, f"image channels {C} vs filter depth {Cg}"
    groups = C // Cg
    ep = dict(scale=scale, bias=bias, act=act)
    ep_on = scale is not None or bias is not None or act is not None
    if choice is not None:
        algorithm, params = choice.algorithm, dict(choice.params)
    else:
        params = {}
    if algorithm == "xla":
        return ref.apply_epilogue(
            ref.conv2d_reference(x, w, stride=stride, padding=padding,
                                 groups=groups), **ep)

    # ---- grouped family: depthwise kernel or XLA fallback ------------
    if groups > 1:
        if algorithm == "auto":
            algorithm, params = _auto(x, w, stride, epilogue=ep_on)
        depthwise_ok = groups == C and K % C == 0 and stride in (1, 2)
        if algorithm != "depthwise" or not depthwise_ok:
            # tuner punted, or a grouped-but-not-depthwise conv
            return ref.apply_epilogue(
                ref.conv2d_reference(x, w, stride=stride, padding=padding,
                                     groups=groups), **ep)
        xp = ref.pad_same(x, R, S, stride=stride) if padding == "SAME" else x
        return ops.dispatch("depthwise", xp, w, impl=impl, stride=stride,
                            **ep, **params)

    if stride != 1 and (R, S) == (stride, stride) and padding == "VALID":
        # non-overlapping patch conv (ViT patch embed): degenerate ILP-M
        # — a single "tap block", i.e. reshape + matmul, K on lanes.
        B, H, W, _ = x.shape
        hp, wp = H // stride, W // stride
        xr = x[:, :hp * stride, :wp * stride].reshape(
            B, hp, stride, wp, stride, C).transpose(0, 1, 3, 2, 4, 5)
        xr = xr.reshape(B, hp * wp, stride * stride * C)
        y = jnp.einsum("bpc,ck->bpk", xr, w.reshape(-1, K))
        return ref.apply_epilogue(y.reshape(B, hp, wp, K), **ep)

    if algorithm == "auto":
        algorithm, params = _auto(x, w, stride, epilogue=ep_on)
        if algorithm == "xla":  # tuner punted (e.g. stride > 2)
            return ref.apply_epilogue(
                ref.conv2d_reference(x, w, stride=stride, padding=padding),
                **ep)

    if algorithm == "pointwise":
        if (R, S) != (1, 1):
            algorithm = "ilpm"  # pointwise kernel is 1x1-only -> best dense
        else:
            return ops.dispatch("pointwise", x, w, impl=impl, stride=stride,
                                **ep, **params)

    if stride != 1 and algorithm not in STRIDED_DENSE:
        # im2col/libdnn/winograd have no strided kernels -> best strided
        algorithm = "ilpm"

    if padding == "SAME":
        xp = ref.pad_same(x, R, S, stride=stride)
    elif padding == "VALID":
        xp = x
    else:
        raise ValueError(padding)

    if algorithm == "winograd":
        H, W = xp.shape[1] - R + 1, xp.shape[2] - S + 1
        if (R, S) != (3, 3) or H % 2 or W % 2:
            algorithm = "ilpm"  # winograd F(2,3) inapplicable -> best direct
        elif u is not None:
            params["u"] = u
    return ops.dispatch(algorithm, xp, w, impl=impl, stride=stride,
                        **ep, **params)


# ---- fused blocks: one dispatch where the per-layer path makes 2-3 ----

def block_inverted_residual(x, p, choice, *, stride=1, residual=False,
                            impl="auto"):
    """Run a whole inverted-residual block as one fused dispatch.

    ``p`` is the model's param subtree for the block — optional ``pw1``
    plus ``dw``/``pw2``, each a ``{"w", "scale", "bias"}`` conv site —
    flattened here into the stage-keyed weights dict the block kernel
    takes. ``choice`` is the plan's block-site Choice (algorithm +
    tuned ``block_m``); activations are MobileNetV2's fixed ReLU6 /
    linear-projection pattern, so they're call-site constants, not plan
    state.
    """
    weights = {"wdw": p["dw"]["w"], "sdw": p["dw"]["scale"],
               "bdw": p["dw"]["bias"],
               "w2": p["pw2"]["w"], "s2": p["pw2"]["scale"],
               "b2": p["pw2"]["bias"]}
    if "pw1" in p:
        weights.update({"w1": p["pw1"]["w"], "s1": p["pw1"]["scale"],
                        "b1": p["pw1"]["bias"]})
    return ops.dispatch_block(choice.algorithm, x, weights, impl=impl,
                              stride=stride, residual=residual, act="relu6",
                              out_act=None, **dict(choice.params))


def block_residual_conv(x, p, choice, *, res, impl="auto"):
    """Run a ResNet block's final conv with the shortcut add + outer ReLU
    fused into its output write. ``p`` is the conv's ``{"w", "scale",
    "bias"}`` site; ``res`` the identity/projection branch; SAME padding
    applied here (the fused kernel is stride-1 by construction)."""
    w = p["w"]
    xp = ref.pad_same(x, w.shape[0], w.shape[1])
    weights = {"w": w, "scale": p["scale"], "bias": p["bias"]}
    return ops.dispatch_block(choice.algorithm, xp, weights, impl=impl,
                              res=res, act="relu", **dict(choice.params))
