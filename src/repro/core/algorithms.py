"""Public convolution entry point with algorithm selection.

``conv2d(x, w, algorithm=...)`` is how the framework consumes the paper's
contribution: 'ilpm' | 'direct' | 'im2col' | 'libdnn' | 'winograd' run the
corresponding kernels; 'auto' asks the autotuner; 'xla' is the
lax.conv_general_dilated escape hatch (used for 1x1/strided convs where the
paper's algorithms don't apply). Passing an explicit autotuner ``choice``
(a ``repro.core.autotune.Choice``) pins both the algorithm *and* its tuned
kernel parameters (``block_k``/``block_h``) — this is how a TuningPlan's
per-layer decisions reach the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.convspec import ConvSpec
from repro.kernels import ops, ref


def conv2d(x, w, *, stride=1, padding="SAME", algorithm="auto", impl="auto",
           choice=None):
    """x: (B,H,W,C) NHWC; w: (R,S,C,K) HWIO -> (B,H',W',K)."""
    R, S, C, K = w.shape
    if choice is not None:
        algorithm, params = choice.algorithm, dict(choice.params)
    else:
        params = {}
    if algorithm == "xla":
        return ref.conv2d_reference(x, w, stride=stride, padding=padding)

    if stride != 1:
        if (R, S) == (stride, stride) and padding == "VALID":
            # non-overlapping patch conv (ViT patch embed): degenerate ILP-M
            # — a single "tap block", i.e. reshape + matmul, K on lanes.
            B, H, W, _ = x.shape
            hp, wp = H // stride, W // stride
            xr = x[:, :hp * stride, :wp * stride].reshape(
                B, hp, stride, wp, stride, C).transpose(0, 1, 3, 2, 4, 5)
            xr = xr.reshape(B, hp * wp, stride * stride * C)
            y = jnp.einsum("bpc,ck->bpk", xr, w.reshape(-1, K))
            return y.reshape(B, hp, wp, K)
        # general strided conv: outside the paper's scope (its layers are
        # stride-1 3x3) — XLA path, noted in DESIGN.md
        return ref.conv2d_reference(x, w, stride=stride, padding=padding)

    if algorithm == "auto":
        spec = ConvSpec.from_tensors(x, w, stride)
        tuned = autotune.select(spec)
        algorithm, params = tuned.algorithm, dict(tuned.params)
        if algorithm == "xla":  # tuner punted (e.g. 1x1): reference path
            return ref.conv2d_reference(x, w, stride=stride, padding=padding)

    if padding == "SAME":
        xp = ref.pad_same(x, R, S)
    elif padding == "VALID":
        xp = x
    else:
        raise ValueError(padding)

    if algorithm == "winograd":
        H, W = xp.shape[1] - R + 1, xp.shape[2] - S + 1
        if (R, S) != (3, 3) or H % 2 or W % 2:
            algorithm = "ilpm"  # winograd F(2,3) inapplicable -> best direct
    return ops.dispatch(algorithm, xp, w, impl=impl, **params)
