"""Single-image CNN inference engine — the paper's deployment scenario.

Wraps a CNN (ResNet or a MobileNet-style net) with the paper's
tune-once/run-many flow (§2.3):

  1. the model module's ``conv_specs`` enumerates the ConvSpec of every
     conv site in the network — for ResNet the 7x7/2 stem, every 3x3
     (strided stage entries included) and every 1x1 (bottleneck
     reduce/expand, projection shortcuts); for MobileNet the stem plus
     every depthwise and pointwise site, strided depthwise included;
  2. the autotuner turns that list into a ``TuningPlan`` (cost-model or
     measured mode) mapping each layer name to its tuned Choice —
     algorithm plus kernel parameters — costed as the fused conv+BN+act
     variant the forwards actually dispatch;
  3. the plan is threaded into the model's ``forward`` and jitted, so the
     compiled forward dispatches each layer to its own tuned kernel with
     its folded-BN/activation epilogue fused into the kernel; Winograd
     sites get their filter transform ``U = G g Gᵀ`` computed once here
     and cached for every subsequent forward;
  4. plans serialize to JSON (``save_plan`` / ``TuningPlan.load``) so a
     device tunes once offline and deployments just load the plan.

The per-layer traffic/FLOP report doubles as the energy proxy (paper §2.2:
off-chip traffic dominates edge energy).
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.autotune import TuningPlan
from repro.core.convspec import ConvSpec
from repro.models.registry import cnn_module
from repro.models.spec import init_params


@dataclass
class LayerReport:
    name: str
    spec: ConvSpec
    algorithm: str
    est_time: float
    est_bytes: int
    est_flops: int
    params: tuple = ()


class InferenceEngine:
    """Tune-once, run-many single-image inference.

    ``algorithm="auto"`` tunes a per-layer plan (``tune_mode`` picks
    cost-model vs measured); a concrete algorithm name forces every 3x3
    conv onto that algorithm; ``plan=`` (a TuningPlan or a JSON path)
    skips tuning and deploys a saved plan.
    """

    def __init__(self, cfg, params=None, seed=0, algorithm="auto",
                 plan=None, tune_mode="cost_model"):
        assert cfg.family == "cnn"
        self.cfg = cfg
        self._model = cnn_module(cfg)
        self.params = params if params is not None else init_params(
            self._model.model_specs(cfg), seed, cfg.param_dtype)
        self.algorithm = algorithm
        if plan is not None and not isinstance(plan, TuningPlan):
            plan = TuningPlan.load(plan)  # a path: tune-once/deploy-many
        if plan is not None:
            self._validate_plan(plan)
        elif algorithm == "auto":
            plan = self.tune(mode=tune_mode)
        self.plan = plan
        self.reports = self._reports_from_plan(plan) if plan else []
        # Winograd filter transforms U = G g G^T are constant at inference
        # (weights frozen): compute each winograd site's U once now, not
        # per forward, and thread the cache into the jitted forward.
        self.winograd_u = self._winograd_cache(plan) if plan else {}
        # winograd_u rides as a jit *argument* (a pytree, like params),
        # not a closure constant: baked-in constants would be re-embedded
        # into every trace of every entry point below.
        # The forward consumes ONE name->Choice dict: per-conv choices plus
        # the tuner's block-level fusion decisions (`<block>.block` keys are
        # disjoint from conv-site keys). At fused sites the forward
        # dispatches the block megakernel and skips the constituent convs'
        # entries entirely.
        fwd1 = functools.partial(
            self._model.forward, cfg=cfg, algorithm=algorithm,
            plan={**plan.choices, **plan.block_choices}
            if plan is not None else None)
        self._fwd = jax.jit(fwd1)
        # Batch-dim-tolerant entry for the serving layer: map the *exact*
        # single-image computation over the batch inside one jitted call
        # (lax.map), so a micro-batched dispatch is bitwise-equal to N
        # sequential `run` calls — batching changes scheduling, never
        # numerics. One retrace per distinct B; serving pads batches to
        # power-of-two buckets to bound the trace count.
        self._fwd_batch = jax.jit(
            lambda params, images, winograd_u=None: jax.lax.map(
                lambda im: fwd1(params, images=im[None],
                                winograd_u=winograd_u)[0], images))
        # Streaming entry: the same single-image computation as `run`,
        # jitted with the frame buffer DONATED. A StreamSession
        # device_puts frame t+1 into a fresh slot while frame t computes
        # (double-buffering), and donation lets XLA reuse frame t's input
        # buffer instead of allocating per frame. On backends where no
        # output can alias the frame (CPU; logits are far smaller than
        # the image) XLA declines the donation with a UserWarning —
        # benign, so it's filtered rather than spamming every stream.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        self._fwd_stream = jax.jit(fwd1, donate_argnames=("images",))

    # ------------------------------------------------------------------
    # plan construction

    def _conv_specs(self):
        """(name, ConvSpec) per planned conv site, keyed like the params.

        Delegated to the model module (``resnet.conv_specs`` /
        ``mobilenet.conv_specs``), which walks the exact geometry of its
        ``forward``.
        """
        return self._model.conv_specs(self.cfg)

    def _block_specs(self):
        """(name, FusedBlockSpec) per fusible block site, or () for model
        families without a block enumeration — block tuning is opt-in per
        model module, and a model that never grows one simply keeps
        per-layer plans."""
        fn = getattr(self._model, "block_specs", None)
        return fn(self.cfg) if fn is not None else ()

    def tune(self, mode="cost_model", **tune_kwargs) -> TuningPlan:
        """Build the per-layer TuningPlan (the offline step of §2.3).

        ``tune_kwargs`` reach the tuner: ``repeats`` and ``noise_floor``
        for measured mode (on real hardware use ``noise_floor=0`` for
        pure wall-clock selection). Sites are costed as their fused
        conv+BN+act variants (``epilogue=True``) because that is what the
        model forwards dispatch. Block sites (the model's ``block_specs``
        enumeration) tune alongside: sites where a fused megakernel beats
        the per-layer baseline get ``block_choices`` entries.
        """
        return autotune.build_plan(self._conv_specs(), mode=mode,
                                   epilogue=True,
                                   block_specs=self._block_specs(),
                                   **tune_kwargs)

    def _site_params(self, name: str):
        """Resolve a plan layer name ('s0b1.c2') to its param subtree."""
        p = self.params
        for part in name.split("."):
            p = p[part]
        return p

    def _winograd_cache(self, plan: TuningPlan) -> dict:
        """U = G g G^T per winograd-planned site, computed once per build
        (the paper's §5.2 'filter transform is free at inference')."""
        from repro.kernels import ref as _ref

        cache = {}
        for name, ch in plan.choices.items():
            if ch.algorithm != "winograd":
                continue
            try:
                w = self._site_params(name)["w"]
            except (KeyError, TypeError):
                continue  # plan site not in this param tree: skip
            # the transform einsums against fp32 G matrices (promoting the
            # result); cast back so a bf16/fp16 engine streams U at the
            # engine's element width, matching the cost model's accounting
            cache[name] = _ref.winograd_filter_transform(w).astype(w.dtype)
        return cache

    def _validate_plan(self, plan: TuningPlan) -> None:
        """A deployed plan must match this network's conv geometry *and*
        precision — ConvSpec carries ``dtype``, so a plan tuned in fp32
        cannot be deployed onto a bf16 engine (byte traffic, and therefore
        the tuned choices, differ)."""
        import logging

        ours = dict(self._conv_specs())
        mismatched = {n for n, spec in plan.specs.items()
                      if n in ours and ours[n] != spec}
        if mismatched:
            raise ValueError(
                f"tuning plan was built for a different network/input "
                f"size/dtype (engine dtype {self.cfg.dtype!r}); "
                f"mismatched specs for {sorted(mismatched)}")
        missing = ours.keys() - plan.specs.keys()
        extra = plan.specs.keys() - ours.keys()
        if missing or extra:
            logging.getLogger(__name__).warning(
                "tuning plan coverage mismatch: missing=%s (these layers "
                "fall back to untuned dispatch) extra=%s (ignored)",
                sorted(missing), sorted(extra))
        # Block sites: intersection-only (a plan with no/fewer fused sites
        # just runs per-layer there — fusion is an optimization, never a
        # coverage obligation), but a present block entry must match this
        # network's geometry AND dtype exactly, same contract as convs.
        our_blocks = dict(self._block_specs())
        bad_blocks = {n for n, bspec in plan.block_specs.items()
                      if n in our_blocks and our_blocks[n] != bspec}
        if bad_blocks:
            raise ValueError(
                f"tuning plan was built for a different network/input "
                f"size/dtype (engine dtype {self.cfg.dtype!r}); "
                f"mismatched block specs for {sorted(bad_blocks)}")

    def save_plan(self, path) -> None:
        assert self.plan is not None, "engine has no plan to save"
        self.plan.save(path)

    @staticmethod
    def _reports_from_plan(plan: TuningPlan):
        return [LayerReport(name, plan.specs[name], ch.algorithm,
                            ch.est_time, ch.est_bytes, ch.est_flops,
                            ch.params)
                for name, ch in plan.choices.items()]

    # ------------------------------------------------------------------

    def run(self, image):
        """image: (H, W, 3) single image -> logits (classes,)."""
        return self._fwd(self.params, images=image[None],
                         winograd_u=self.winograd_u or None)[0]

    def run_batch(self, images):
        """images: (B, H, W, 3) micro-batch -> logits (B, classes).

        Each element runs the identical batch-1 computation `run`
        dispatches (same tuned per-layer kernels, same epilogues), mapped
        inside one jitted call — outputs are bitwise-equal to sequential
        `run` calls. This is the serving layer's dispatch entry.
        """
        return self._fwd_batch(self.params, images,
                               winograd_u=self.winograd_u or None)

    def device_put_frame(self, image):
        """Start the async host→device transfer of one streaming frame;
        returns the (1, H, W, C) device buffer for ``run_stream``.

        Called at frame *arrival* (on the producer thread), so the
        transfer overlaps the in-flight frame's compute — the streaming
        double-buffer. ``image`` is (H, W, C) or already (1, H, W, C).
        """
        if getattr(image, "ndim", 3) == 3:
            image = image[None]
        return jax.device_put(image)

    def run_stream(self, frames):
        """One streaming frame -> logits (classes,).

        ``frames`` is the (1, H, W, C) device buffer from
        ``device_put_frame``; it is **donated** — dead after this call —
        so callers must hand in a fresh buffer per frame (the session's
        double-buffered slots do). Numerics are identical to ``run``:
        same forward, same tuned per-layer plan, same epilogues.
        """
        return self._fwd_stream(self.params, images=frames,
                                winograd_u=self.winograd_u or None)[0]

    def trace_count(self):
        """Number of distinct shapes the batch forward has been traced
        for (None if this jax version doesn't expose it) — the serving
        tests use it to prove padded buckets bound retraces."""
        size = getattr(self._fwd_batch, "_cache_size", None)
        return size() if callable(size) else None

    def traffic_report(self):
        """Per-layer bytes/flops for every planned conv site — the energy
        proxy (DESIGN.md §7.5). Coverage follows the model module's
        ``conv_specs``: every backbone conv site (stem, strided entries,
        1x1s, depthwise/pointwise) has an entry."""
        return self.reports
