"""Single-image CNN inference engine — the paper's deployment scenario.

Wraps a CNN (ResNet here) with: per-layer algorithm tuning (once, offline —
paper §2.3), a jitted single-image forward, and traffic/FLOP accounting per
layer for the energy-proxy report (paper §2.2: off-chip traffic dominates
edge energy).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.convspec import ConvSpec
from repro.models import resnet
from repro.models.spec import init_params


@dataclass
class LayerReport:
    name: str
    spec: ConvSpec
    algorithm: str
    est_time: float
    est_bytes: int
    est_flops: int


class InferenceEngine:
    """Tune-once, run-many single-image inference."""

    def __init__(self, cfg, params=None, seed=0, algorithm="auto"):
        assert cfg.family == "cnn"
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            resnet.model_specs(cfg), seed, cfg.param_dtype)
        self.algorithm = algorithm
        self.reports = self._tune() if algorithm == "auto" else []
        self._fwd = jax.jit(functools.partial(
            resnet.forward, cfg=cfg,
            algorithm=self._tuned_algorithm()))

    def _conv_specs(self):
        """Every 3x3 conv layer's ConvSpec for the configured input size."""
        img = self.cfg.extra["img"]
        blocks = self.cfg.extra["blocks"]
        widths = [64, 128, 256, 512]
        sizes = [img // 4, img // 8, img // 16, img // 32]
        specs = []
        for si, n in enumerate(blocks):
            c = widths[si]
            h = sizes[si]
            specs.append((f"s{si}", ConvSpec(h=h, w=h, c=c, k=c)))
        return specs

    def _tune(self):
        out = []
        for name, spec in self._conv_specs():
            ch = autotune.select(spec)
            out.append(LayerReport(name, spec, ch.algorithm, ch.est_time,
                                   ch.est_bytes, ch.est_flops))
        return out

    def _tuned_algorithm(self):
        if self.algorithm != "auto":
            return self.algorithm
        # single dominant choice (the tuner picks per-layer; the jitted
        # forward takes one algorithm arg — per-layer dispatch goes through
        # algorithms.conv2d('auto') inside the model)
        return "auto"

    def run(self, image):
        """image: (H, W, 3) single image -> logits (classes,)."""
        return self._fwd(self.params, images=image[None])[0]

    def traffic_report(self):
        """Per-stage bytes/flops — the energy proxy (DESIGN.md §7.5)."""
        return self.reports
