"""repro: ILP-M convolution as a production multi-pod JAX/TPU framework.

Public API surface:
    repro.core        — conv2d / autotuner / single-image InferenceEngine
    repro.kernels     — Pallas kernels (ilpm + the paper's 4 baselines,
                        depthwise/pointwise for MobileNet-style nets)
    repro.configs     — the 10 assigned architectures (+ ResNet) + shapes
    repro.launch      — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
