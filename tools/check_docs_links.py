#!/usr/bin/env python
"""Markdown link checker for the documentation surface (no dependencies).

Scans the given markdown files/directories for inline links and validates
every *local* target: relative file links must resolve to an existing file
or directory, and fragment links into a markdown file must match one of its
headings (GitHub anchor convention). External (http/https/mailto) links are
reported but not fetched — CI must stay offline-deterministic.

    python tools/check_docs_links.py README.md docs

Exits 1 listing every broken link, so stale doc references fail fast.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def _anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, drop punctuation,
    spaces to dashes)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {_anchor(h) for h in HEADING_RE.findall(text)}


def check_file(md_path: Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue  # external: listed as out of scope, never fetched
        path_part, _, fragment = target.partition("#")
        dest = md_path if not path_part \
            else (md_path.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md_path}: broken link -> {target}")
            continue
        if fragment and dest.suffix == ".md":
            if _anchor(fragment) not in _anchors(dest):
                errors.append(f"{md_path}: missing anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [Path("README.md"), Path("docs")]
    files: list[Path] = []
    for root in roots:
        if root.is_dir():
            files.extend(sorted(root.rglob("*.md")))
        elif root.exists():
            files.append(root)
        else:
            print(f"check_docs_links: no such path {root}", file=sys.stderr)
            return 2
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs_links: {len(files)} files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
