"""Compare a bench artifact against the committed baseline — the CI
perf-regression gate.

Usage:  python tools/compare_bench.py BASELINE CANDIDATE
            [--proxy-tolerance 0.25] [--est-tolerance 0.10]
            [--miss-tolerance 0.0]

Four artifact kinds are accepted, auto-detected from the payload (an
explicit top-level ``"kind"`` field wins; the structural fallbacks below
cover older artifacts):

  * **conv** (``BENCH_conv.json``, has ``layers``) — the per-layer
    algorithm/cost gate described below;
  * **streaming** (``BENCH_streaming.json``, has ``scenarios``) — the
    deadline gate: per scenario, the simulated-clock deadline-miss rate
    and frame-drop rate must not exceed the baseline by more than
    ``--miss-tolerance`` (absolute; the simulation is deterministic, so
    the default tolerance is 0);
  * **serving** (``BENCH_serving.json``, ``"kind": "serving"``) — the
    overload gate: the overload scenario must actually shed
    (``shed_rate > 0``, within ``--shed-tolerance`` of the baseline),
    every accepted request must resolve (``unresolved == 0``), and
    accepted-request p95 latency must stay under the scenario's
    ``p95_bound_s`` — bounded queues trade rejections for bounded
    latency, and this gate holds both halves of that trade; the sweep
    scenario extends this to the whole SLO curve (per-rung p95 bound,
    zero shed below saturation, monotone shed above it);
  * **quant** (``BENCH_quant.json``, has ``rows``) — the
    accuracy-vs-speed gate: per precision row, top-1 agreement with the
    fp32 reference must not drop below the baseline by more than
    ``--agreement-tolerance`` (absolute), the max relative logit error
    must not blow up (> 2x baseline and above a 1e-4 floor), no site may
    newly fall back to ``xla`` in a reduced precision, and the
    cost-model ``est_time_s`` gates like conv's (``--est-tolerance``).

Checks, over the layers present in BOTH files (new/removed layers are
informational, so adding a network or a conv site never breaks the gate):

  1. **algorithm regression** — any site that had a tuned (non-``xla``)
     algorithm in the baseline but falls back to ``xla`` in the candidate
     fails the build: a kernel or tuner change silently dropped a site
     out of the paper's tuned path.
  2. **cost-model regression** — total ``est_time_s`` (deterministic, no
     machine noise) grew by more than ``--est-tolerance``.
  3. **interpret-proxy regression** — total ``interpret_time_s`` (CPU
     wall-clock of the chosen kernels, a noisy trend line) grew by more
     than ``--proxy-tolerance``; sites missing a timing on either side
     are skipped.

Exit code 0 = clean (algorithm *changes* between tuned kernels are
reported but allowed — the tuner is free to re-decide), 1 = regression.

The proxy check compares wall-clock against a baseline measured on a
(possibly different) machine, so it is the gate's noisiest leg: when the
layer set or the CI runner class legitimately changes, refresh the
committed baseline (``make bench-json && cp BENCH_conv.json
benchmarks/baseline/``) rather than widening ``--proxy-tolerance``.
"""
from __future__ import annotations

import argparse
import json
import sys


def _layers(payload: dict) -> dict:
    return {l["layer"]: l for l in payload["layers"]}


def compare(baseline: dict, candidate: dict, *, proxy_tolerance: float = 0.25,
            est_tolerance: float = 0.10) -> tuple[list[str], list[str]]:
    """-> (problems, notes). Nonempty problems means the gate fails."""
    problems, notes = [], []
    base, cand = _layers(baseline), _layers(candidate)
    common = sorted(base.keys() & cand.keys())
    if not common:
        return ["no common layers between baseline and candidate"], notes
    only_base = sorted(base.keys() - cand.keys())
    only_cand = sorted(cand.keys() - base.keys())
    if only_base:
        notes.append(f"layers only in baseline (skipped): {only_base}")
    if only_cand:
        notes.append(f"new layers not in baseline (skipped): {only_cand}")

    for name in common:
        b_alg, c_alg = base[name]["algorithm"], cand[name]["algorithm"]
        if c_alg == "xla" and b_alg != "xla":
            problems.append(
                f"{name}: tuned algorithm regressed to the xla escape "
                f"hatch (baseline: {b_alg})")
        elif b_alg != c_alg:
            notes.append(f"{name}: algorithm changed {b_alg} -> {c_alg}")

    def total(layers, field, names):
        vals = [layers[n][field] for n in names]
        return None if any(v is None for v in vals) else sum(vals)

    b_est = total(base, "est_time_s", common)
    c_est = total(cand, "est_time_s", common)
    if b_est and c_est is not None and c_est > b_est * (1 + est_tolerance):
        problems.append(
            f"cost-model total est_time regressed "
            f"{c_est / b_est - 1:+.1%} (> {est_tolerance:.0%} allowed): "
            f"{b_est:.3e}s -> {c_est:.3e}s")

    # --- fused-coverage gate (v2 artifacts carry ``blocks`` rows) ------
    # A block site the baseline ran FUSED must stay fused: regressing to
    # the per-layer path silently reintroduces the HBM round-trips the
    # megakernel deleted. New fusions are notes; artifacts without a
    # blocks section (pre-fusion baselines) skip the check entirely.
    b_blocks = {b["block"]: b for b in baseline.get("blocks", [])}
    c_blocks = {b["block"]: b for b in candidate.get("blocks", [])}
    for name in sorted(b_blocks.keys() & c_blocks.keys()):
        was, now = b_blocks[name].get("fused"), c_blocks[name].get("fused")
        if was and not now:
            problems.append(
                f"{name}: previously-fused block site regressed to the "
                f"per-layer path")
        elif now and not was:
            notes.append(f"{name}: block site newly fused")
    for name, cb in sorted(c_blocks.items()):
        # the charging invariant: a fused row must actually save traffic
        if cb.get("fused") and cb.get("est_bytes") is not None \
                and cb["est_bytes"] >= cb.get("per_layer_est_bytes",
                                              float("inf")):
            problems.append(
                f"{name}: fused byte estimate {cb['est_bytes']} is not "
                f"below the per-layer sum {cb['per_layer_est_bytes']}")

    timed = [n for n in common
             if base[n].get("interpret_time_s") is not None
             and cand[n].get("interpret_time_s") is not None]
    if timed:
        b_t = sum(base[n]["interpret_time_s"] for n in timed)
        c_t = sum(cand[n]["interpret_time_s"] for n in timed)
        if b_t and c_t > b_t * (1 + proxy_tolerance):
            problems.append(
                f"interpret-proxy total regressed {c_t / b_t - 1:+.1%} "
                f"(> {proxy_tolerance:.0%} allowed): "
                f"{b_t:.3f}s -> {c_t:.3f}s over {len(timed)} layers")
        else:
            notes.append(
                f"interpret-proxy total {c_t / b_t - 1:+.1%} vs baseline "
                f"over {len(timed)} layers")
    return problems, notes


def compare_streaming(baseline: dict, candidate: dict, *,
                      miss_tolerance: float = 0.0) -> tuple[list[str],
                                                            list[str]]:
    """Streaming-artifact gate: per-scenario deadline-miss / frame-drop
    rates (deterministic simulated-clock numbers) must not exceed the
    baseline by more than ``miss_tolerance`` (absolute). Wall-clock
    fields (classify latencies, real fps) are informational only —
    machine-dependent, never gated. -> (problems, notes)."""
    problems, notes = [], []
    base, cand = baseline["scenarios"], candidate["scenarios"]
    common = sorted(base.keys() & cand.keys())
    if not common:
        return ["no common scenarios between baseline and candidate"], notes
    for only, payload in (("baseline", base.keys() - cand.keys()),
                          ("candidate", cand.keys() - base.keys())):
        if payload:
            notes.append(f"scenarios only in {only} (skipped): "
                         f"{sorted(payload)}")
    for name in common:
        b_agg, c_agg = base[name]["aggregate"], cand[name]["aggregate"]
        for rate in ("deadline_miss_rate", "drop_rate"):
            b, c = b_agg.get(rate), c_agg.get(rate)
            if b is None or c is None:
                continue
            if c > b + miss_tolerance:
                problems.append(
                    f"{name}: {rate} regressed {b:.3f} -> {c:.3f} "
                    f"(> +{miss_tolerance:.3f} allowed)")
            elif c != b:
                notes.append(f"{name}: {rate} changed {b:.3f} -> {c:.3f}")
        if b_agg.get("frames") != c_agg.get("frames"):
            notes.append(f"{name}: frame count changed "
                         f"{b_agg.get('frames')} -> {c_agg.get('frames')}")
    return problems, notes


def compare_quant(baseline: dict, candidate: dict, *,
                  agreement_tolerance: float = 0.13,
                  est_tolerance: float = 0.10) -> tuple[list[str],
                                                        list[str]]:
    """Quant-artifact gate: per precision row (matched by dtype),

      * top-1 agreement with the fp32 reference must not drop below the
        baseline by more than ``agreement_tolerance`` (absolute — the
        default allows one flipped image out of the standard 8, tolerating
        cross-platform float wiggle without masking a real accuracy loss);
      * max relative logit error must not exceed 2x the baseline once it
        is above a 1e-4 floor (fp32's own row sits at ~0 — the floor keeps
        harmless last-ulp noise from tripping the 2x ratio);
      * a reduced-precision row must not *newly* report xla fallback
        sites: a tuned site escaping the kernel path only in low
        precision is exactly the regression this artifact exists to catch;
      * cost-model ``est_time_s`` gates like the conv artifact's
        (``est_tolerance``, relative) — the speed half of the trade.

    -> (problems, notes)."""
    problems, notes = [], []
    base = {r["dtype"]: r for r in baseline["rows"]}
    cand = {r["dtype"]: r for r in candidate["rows"]}
    common = sorted(base.keys() & cand.keys())
    if not common:
        return ["no common precision rows between baseline and candidate"], \
            notes
    for only, rows in (("baseline", base.keys() - cand.keys()),
                       ("candidate", cand.keys() - base.keys())):
        if rows:
            notes.append(f"precision rows only in {only} (skipped): "
                         f"{sorted(rows)}")
    for dt in common:
        b, c = base[dt], cand[dt]
        b_agree, c_agree = b["top1_agreement"], c["top1_agreement"]
        if c_agree < b_agree - agreement_tolerance:
            problems.append(
                f"{dt}: top-1 agreement regressed {b_agree:.3f} -> "
                f"{c_agree:.3f} (> -{agreement_tolerance:.2f} allowed)")
        elif c_agree != b_agree:
            notes.append(f"{dt}: top-1 agreement changed "
                         f"{b_agree:.3f} -> {c_agree:.3f}")
        b_err, c_err = b["logit_rel_err"], c["logit_rel_err"]
        if c_err > max(2 * b_err, 1e-4):
            problems.append(
                f"{dt}: logit rel err blew up {b_err:.2e} -> {c_err:.2e} "
                f"(> 2x baseline allowed)")
        new_xla = sorted(set(c.get("xla_sites", []))
                         - set(b.get("xla_sites", [])))
        if new_xla:
            problems.append(
                f"{dt}: sites newly fell back to xla in this precision: "
                f"{new_xla}")
        b_est, c_est = b.get("est_time_s"), c.get("est_time_s")
        if b_est and c_est is not None \
                and c_est > b_est * (1 + est_tolerance):
            problems.append(
                f"{dt}: cost-model est_time regressed "
                f"{c_est / b_est - 1:+.1%} (> {est_tolerance:.0%} allowed)")
        if b.get("weight_bytes") != c.get("weight_bytes"):
            notes.append(f"{dt}: weight bytes changed "
                         f"{b.get('weight_bytes')} -> "
                         f"{c.get('weight_bytes')}")
    return problems, notes


def _compare_sweep_scenario(name: str, b: dict, c: dict, *,
                            shed_tolerance: float) -> tuple[list[str],
                                                            list[str]]:
    """The SLO-curve gate: per rung of the candidate's offered-QPS
    ladder (matched to the baseline by ``load_factor``),

      * **below saturation** (load_factor < 1) the server must hold a
        clean SLO: ``shed_rate == 0`` and p95 under the artifact's own
        derived ``p95_bound_s`` (machine-portable — the bound travels in
        the artifact);
      * **above saturation** shedding must engage (rate > 0, within
        ``shed_tolerance`` of the baseline rung) while accepted p95
        stays under the same bound;
      * the candidate's shed curve must be **monotone non-decreasing**
        in offered load — admission control that sheds *less* at higher
        load is broken even if every individual rung looks plausible;
      * ``unresolved == 0`` at every rung.

    -> (problems, notes)."""
    problems, notes = [], []
    b_rungs = {r["load_factor"]: r for r in b.get("rungs", [])}
    c_rungs = {r["load_factor"]: r for r in c.get("rungs", [])}
    if not c_rungs:
        return [f"{name}: candidate sweep has no rungs"], notes
    for only, lfs in (("baseline", b_rungs.keys() - c_rungs.keys()),
                      ("candidate", c_rungs.keys() - b_rungs.keys())):
        if lfs:
            notes.append(f"{name}: rungs only in {only} (skipped): "
                         f"{sorted(lfs)}")
    bound = c.get("p95_bound_s")
    for lf in sorted(c_rungs):
        r = c_rungs[lf]
        tag = f"{name}[{lf:g}x]"
        if r.get("unresolved", 0):
            problems.append(
                f"{tag}: {r['unresolved']} accepted request(s) never "
                f"resolved — every admitted Ticket must settle")
        rate = r.get("shed_rate")
        if lf < 1.0:
            if rate:
                problems.append(
                    f"{tag}: shed_rate {rate:.3f} below saturation — an "
                    f"unloaded server must not reject")
        else:
            if rate is not None and rate <= 0:
                problems.append(
                    f"{tag}: shed_rate is 0 at {lf:g}x capacity — the "
                    f"admission bound is not being enforced")
            b_rate = b_rungs.get(lf, {}).get("shed_rate")
            if b_rate is not None and rate is not None:
                if abs(rate - b_rate) > shed_tolerance:
                    problems.append(
                        f"{tag}: shed_rate moved {b_rate:.3f} -> "
                        f"{rate:.3f} (> ±{shed_tolerance:.2f} allowed)")
                elif rate != b_rate:
                    notes.append(f"{tag}: shed_rate changed "
                                 f"{b_rate:.3f} -> {rate:.3f}")
        p95 = r.get("p95_s")
        if p95 is not None and bound is not None and p95 > bound:
            problems.append(
                f"{tag}: p95 {p95:.3f}s exceeds the {bound:.3f}s bound — "
                f"the SLO curve is no longer holding")
    # monotone shed: higher offered load must never shed a lower rate
    ordered = [c_rungs[lf].get("shed_rate") for lf in sorted(c_rungs)]
    ordered = [r for r in ordered if r is not None]
    if any(lo > hi for lo, hi in zip(ordered, ordered[1:])):
        problems.append(
            f"{name}: shed curve is non-monotone in offered load "
            f"({[round(r, 3) for r in ordered]}) — admission control is "
            f"load-dependent in the wrong direction")
    return problems, notes


def compare_serving(baseline: dict, candidate: dict, *,
                    shed_tolerance: float = 0.3) -> tuple[list[str],
                                                          list[str]]:
    """Serving-artifact gate. The overload scenario carries the one-point
    invariants and the sweep scenario (``rungs``) the whole SLO curve
    (``_compare_sweep_scenario``); throughput numbers are wall-clock
    trend lines — noted, never gated:

      * **every accepted request resolved** — ``unresolved`` must be 0:
        an admitted Future that never settles is the worst serving bug
        this subsystem can have, worse than any rejection;
      * **overload actually sheds** — ``shed_rate`` must be > 0 (the
        scenario offers ~2x+ capacity; zero shed means the admission
        bound silently stopped being enforced and the queue is unbounded
        again) and within ``shed_tolerance`` (absolute) of the baseline
        rate in either direction;
      * **bounded accepted latency** — ``accepted_p95_s`` must stay under
        the scenario's own ``p95_bound_s``: shedding exists precisely so
        admitted requests keep a bounded queue ahead of them.

    -> (problems, notes)."""
    problems, notes = [], []
    base, cand = baseline["scenarios"], candidate["scenarios"]
    common = sorted(base.keys() & cand.keys())
    if not common:
        return ["no common scenarios between baseline and candidate"], notes
    for only, names in (("baseline", base.keys() - cand.keys()),
                        ("candidate", cand.keys() - base.keys())):
        if names:
            notes.append(f"scenarios only in {only} (skipped): "
                         f"{sorted(names)}")
    for name in common:
        b, c = base[name], cand[name]
        if "rungs" in b or "rungs" in c:  # the load-sweep (SLO curve) leg
            problems_, notes_ = _compare_sweep_scenario(
                name, b, c, shed_tolerance=shed_tolerance)
            problems.extend(problems_)
            notes.extend(notes_)
            continue
        if "shed_rate" in b or "shed_rate" in c:  # the overload leg
            if c.get("unresolved", 0):
                problems.append(
                    f"{name}: {c['unresolved']} accepted request(s) never "
                    f"resolved — every admitted Future must settle")
            b_rate, c_rate = b.get("shed_rate"), c.get("shed_rate")
            if b_rate is not None and c_rate is not None:
                if c_rate <= 0:
                    problems.append(
                        f"{name}: shed_rate is 0 under ~2x+ offered load — "
                        f"the admission bound is not being enforced")
                elif abs(c_rate - b_rate) > shed_tolerance:
                    problems.append(
                        f"{name}: shed_rate moved {b_rate:.3f} -> "
                        f"{c_rate:.3f} (> ±{shed_tolerance:.2f} allowed)")
                elif c_rate != b_rate:
                    notes.append(f"{name}: shed_rate changed "
                                 f"{b_rate:.3f} -> {c_rate:.3f}")
            p95, bound = c.get("accepted_p95_s"), c.get("p95_bound_s")
            if p95 is not None and bound is not None and p95 > bound:
                problems.append(
                    f"{name}: accepted-request p95 {p95:.3f}s exceeds the "
                    f"{bound:.3f}s bound — shedding is no longer keeping "
                    f"admitted latency bounded")
            if b.get("offered") != c.get("offered"):
                notes.append(f"{name}: offered load changed "
                             f"{b.get('offered')} -> {c.get('offered')}")
        if "throughput_rps" in b and "throughput_rps" in c:
            notes.append(
                f"{name}: throughput {b['throughput_rps']:.1f} -> "
                f"{c['throughput_rps']:.1f} req/s (wall-clock, not gated)")
    return problems, notes


def _kind(payload: dict) -> str:
    # explicit kind wins: the serving artifact carries "scenarios" too,
    # so duck-typing alone would misread it as a streaming artifact
    k = payload.get("kind")
    if k:
        return k
    if "scenarios" in payload:
        return "streaming"
    if "rows" in payload:
        return "quant"
    return "conv"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--proxy-tolerance", type=float, default=0.25,
                    help="allowed fractional interpret-proxy slowdown")
    ap.add_argument("--est-tolerance", type=float, default=0.10,
                    help="allowed fractional cost-model est_time growth")
    ap.add_argument("--miss-tolerance", type=float, default=0.0,
                    help="allowed absolute deadline-miss/drop rate growth "
                         "(streaming artifacts)")
    ap.add_argument("--agreement-tolerance", type=float, default=0.13,
                    help="allowed absolute top-1 agreement drop per "
                         "precision row (quant artifacts)")
    ap.add_argument("--shed-tolerance", type=float, default=0.3,
                    help="allowed absolute shed-rate drift in the overload "
                         "scenario (serving artifacts)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    kinds = _kind(baseline), _kind(candidate)
    if kinds[0] != kinds[1]:
        print(f"REGRESSION: baseline and candidate are different artifact "
              f"kinds ({kinds[0]} vs {kinds[1]})", file=sys.stderr)
        return 1
    if kinds[0] == "streaming":
        problems, notes = compare_streaming(
            baseline, candidate, miss_tolerance=args.miss_tolerance)
        what = f"{len(candidate['scenarios'])} scenarios"
    elif kinds[0] == "serving":
        problems, notes = compare_serving(
            baseline, candidate, shed_tolerance=args.shed_tolerance)
        what = f"{len(candidate['scenarios'])} serving scenarios"
    elif kinds[0] == "quant":
        problems, notes = compare_quant(
            baseline, candidate,
            agreement_tolerance=args.agreement_tolerance,
            est_tolerance=args.est_tolerance)
        what = f"{len(candidate['rows'])} precision rows"
    else:
        problems, notes = compare(baseline, candidate,
                                  proxy_tolerance=args.proxy_tolerance,
                                  est_tolerance=args.est_tolerance)
        what = (f"{len(candidate['layers'])} candidate layers vs "
                f"{len(baseline['layers'])} baseline")
    for n in notes:
        print(f"note: {n}")
    for p in problems:
        print(f"REGRESSION: {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"bench comparison clean: {what}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
